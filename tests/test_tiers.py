"""Tiered BlockStore: byte budgets, demotion/promotion through the
device → host → disk chain, partial spill, honest residency accounting,
and the background prefetcher.

The differential harness (test_differential.py) runs whole mutation/query
walks under tier pressure; this file pins each tier mechanism
deterministically — budgets are hard ceilings, demotions are loss-free,
spilled partials serve without re-folding, and a prefetched promotion is
claimed with its original classification.
"""

import os
import time

import numpy as np
import pytest

from repro.core.blockstore import BlockStore, DeviceBlock
from repro.core.chunk_model import TierCostModel
from repro.core.grid import GridSession
from repro.core.regions import HierarchicalSplitPolicy, Region
from repro.core.stats import CountProgram, MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

PAYLOAD = (3, 4)
ROW_BYTES = int(np.prod(PAYLOAD)) * 4          # float32 payload row


def make_table(groups=tuple("abcdefghij"), per=4, seed=0):
    """10 presplit regions × 4 rows: payload blocks of 192 B each, so
    byte budgets in the hundreds force every tier transition."""
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=list(groups)[1:],
    )
    keys = [f"{g}{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8)}})
    return t


def gauge_truth(blocks):
    """Recompute per-tier bytes from what the blocks actually hold."""
    dev = host = disk = 0
    for b in blocks._blocks.values():
        if b.device is not None:
            dev += b.device_nbytes
        if b.host is not None and not b.host_mmap:
            host += b.nbytes
        if b.spill_path is not None:
            disk += b.spill_nbytes
    for _p, sz, _t in blocks._spilled_partials.values():
        disk += sz
    return {"device": dev, "host": host, "disk": disk}


def assert_gauges_exact(blocks):
    assert blocks.tier_bytes() == gauge_truth(blocks)


def region(rid=1):
    return Region(rid, bytes([64 + rid]), bytes([65 + rid]))


def fake_device(host, owner):
    """A stand-in device commit: a padded copy with its own nbytes."""
    dev = np.ascontiguousarray(host)
    return dev


# ----------------------------------------------------------------------
# store-level tier mechanics
# ----------------------------------------------------------------------

class TestTierMechanics:
    def _store(self, tmpdir, **kw):
        kw.setdefault("spill_dir", str(tmpdir.join("spill")))
        return BlockStore(cap=None, **kw)

    def _fill(self, bs, n=6, rows=100):
        data = {}
        for rid in range(1, n + 1):
            data[rid] = (np.arange(rows, dtype=np.float64) * rid)
            blk, reused, gathered = bs.fetch(
                region(rid), "img", "data", owner_index=0,
                gather_host=lambda rid=rid: data[rid],
                to_device=fake_device)
            assert gathered and not reused
        return data

    def test_device_budget_demotes_coldest(self, tmpdir):
        bs = self._store(tmpdir, device_budget=2 * 800)
        self._fill(bs)                         # 6 × 800 B device copies
        assert bs.stats.device_bytes <= 1600
        assert bs.stats.demotions == 4
        assert_gauges_exact(bs)
        # demoted content survives one tier down (host), not re-gathered
        blk, reused, gathered = bs.fetch(
            region(1), "img", "data", owner_index=0,
            gather_host=lambda: 1 / 0, to_device=fake_device)
        assert not gathered
        bs.close()

    def test_host_budget_spills_and_mmap_promotes(self, tmpdir):
        bs = self._store(tmpdir, host_budget=3 * 800)
        data = self._fill(bs)
        assert bs.stats.host_bytes <= 2400
        assert bs.stats.spills >= 1
        assert os.listdir(bs.spill_dir)
        assert_gauges_exact(bs)
        # a spilled block re-serves as an mmap view, bytes exact
        blk, gathered = bs.fetch_host(region(1), "img", "data",
                                      gather_host=lambda: 1 / 0)
        assert not gathered and blk.host_mmap
        np.testing.assert_array_equal(np.asarray(blk.host), data[1])
        assert bs.stats.spill_reads >= 1
        assert_gauges_exact(bs)
        bs.close()

    def test_disk_budget_drops_spill_files(self, tmpdir):
        bs = self._store(tmpdir, host_budget=800, disk_budget=2000)
        self._fill(bs)
        assert bs.stats.disk_bytes <= 2000
        assert bs.stats.spill_drops >= 1
        assert_gauges_exact(bs)
        # a fully dropped block re-gathers losslessly
        calls = []
        blk, gathered = bs.fetch_host(
            region(1), "img", "data",
            gather_host=lambda: calls.append(1) or
            np.arange(100, dtype=np.float64))
        assert blk.rows == 100
        bs.close()

    def test_no_spill_dir_drops_instead(self, tmpdir):
        bs = BlockStore(cap=None, host_budget=800, spill_dir=None)
        self._fill(bs)
        assert bs.stats.spills == 0 and bs.stats.spill_drops >= 1
        assert bs.stats.host_bytes <= 800
        assert_gauges_exact(bs)

    def test_cost_model_can_refuse_spill(self, tmpdir):
        # a disk so slow the oracle prefers re-gathering: drops, no files
        slow = TierCostModel(disk_bw_r=1.0, disk_bw_w=1.0)
        bs = self._store(tmpdir, host_budget=800, cost_model=slow)
        self._fill(bs)
        assert bs.stats.spills == 0 and bs.stats.spill_drops >= 1
        assert not os.listdir(bs.spill_dir)
        bs.close()

    def test_oversized_block_never_enters_device_tier(self, tmpdir):
        bs = self._store(tmpdir, device_budget=100)   # < one 800 B block
        blk, reused, gathered = bs.fetch(
            region(1), "img", "data", owner_index=0,
            gather_host=lambda: np.arange(100, dtype=np.float64),
            to_device=fake_device)
        # served host-side, classified transferred (gather ⟹ transfer)
        assert gathered and not reused and blk.device is None
        assert bs.stats.host_serves == 1 and bs.stats.device_bytes == 0
        assert_gauges_exact(bs)
        bs.close()

    def test_resident_nbytes_per_payload(self, tmpdir):
        bs = self._store(tmpdir)
        blk, *_ = bs.fetch(
            region(1), "img", "data", owner_index=0,
            gather_host=lambda: np.arange(100, dtype=np.float64),
            to_device=fake_device)
        # both payloads held: host + device
        assert bs.resident_nbytes() == blk.nbytes + blk.device_nbytes
        # drop the device copy: residency falls to the host copy alone
        # (the pre-tiering accounting kept double-charging here)
        bs.device_budget = 0
        bs._enforce_tiers()
        assert bs.resident_nbytes() == blk.nbytes
        # spill the host copy: nothing pinned in RAM, content on disk
        bs.host_budget = 0
        bs._enforce_tiers()
        assert bs.resident_nbytes() == 0
        assert bs.tier_bytes()["disk"] > 0
        assert_gauges_exact(bs)
        bs.close()

    def test_touch_unlinks_superseded_spill_files(self, tmpdir):
        bs = self._store(tmpdir, host_budget=800)
        self._fill(bs)
        assert os.listdir(bs.spill_dir)
        bs.touch(range(1, 7), epoch=1)
        assert bs.tier_bytes()["disk"] == 0
        assert not os.listdir(bs.spill_dir)
        assert_gauges_exact(bs)
        bs.close()

    def test_close_removes_owned_spill_dir(self, tmpdir):
        bs = self._store(tmpdir, host_budget=800)
        self._fill(bs)
        spill = bs.spill_dir
        assert os.path.isdir(spill)
        bs.close()
        assert not os.path.isdir(spill)
        # close is idempotent and leaves the store usable in-memory
        bs.close()
        blk, g = bs.fetch_host(region(9), "img", "data",
                               gather_host=lambda: np.zeros(4))
        assert g


class TestPartialSpill:
    def test_evicted_partial_demotes_and_serves_without_refold(self, tmpdir):
        bs = BlockStore(cap=None, partial_cap=2,
                        spill_dir=str(tmpdir.join("s")))
        keys = []
        for rid in range(1, 6):
            k = bs.partial_key(region(rid), "img", "data",
                               ("mean",), "full", 4)
            keys.append(k)
            bs.put_partial(k, {"count": np.float64(rid),
                               "sums": np.arange(3.) * rid})
        assert bs.partial_count == 2 and bs.spilled_partial_count == 3
        folds_before = bs.stats.folds
        # a spilled partial promotes back exactly, WITHOUT counting a fold
        p = bs.get_partial(keys[0])
        assert p is not None and float(p["count"]) == 1.0
        np.testing.assert_array_equal(p["sums"], np.arange(3.))
        assert bs.stats.folds == folds_before
        assert bs.stats.partial_spill_reads == 1
        # the index treats spilled partials as servable throughout
        for rid in range(1, 6):
            assert bs.has_partials(rid)
        assert_gauges_exact(bs)
        bs.close()

    def test_refold_supersedes_spilled_copy(self, tmpdir):
        bs = BlockStore(cap=None, partial_cap=1,
                        spill_dir=str(tmpdir.join("s")))
        k1 = bs.partial_key(region(1), "img", "data", ("m",), "full", 4)
        k2 = bs.partial_key(region(2), "img", "data", ("m",), "full", 4)
        bs.put_partial(k1, {"v": np.float64(1)})
        bs.put_partial(k2, {"v": np.float64(2)})   # k1 evicts -> spills
        assert bs.spilled_partial_count == 1
        bs.put_partial(k1, {"v": np.float64(10)})  # fresh fold supersedes
        assert bs.get_partial(k1)["v"] == 10.0
        assert bs.has_partials(1) and bs.has_partials(2)
        assert_gauges_exact(bs)
        bs.close()


# ----------------------------------------------------------------------
# session-level: queries stay exact while everything demotes
# ----------------------------------------------------------------------

class TestTieredSession:
    def test_query_exact_at_10x_device_budget(self, tmpdir):
        """The acceptance scenario: the dataset is 10× the device byte
        budget, every query answers exactly, and no tier ever exceeds
        its budget."""
        t = make_table()                       # 10 regions × 192 B blocks
        total = 40 * ROW_BYTES                 # 1920 B of payload
        with GridSession(t, default_eta=4, device_budget=total // 10,
                         host_budget=total // 2,
                         spill_dir=str(tmpdir.join("s")),
                         prefetch=False) as s:
            expect = t.column("img", "data").astype(np.float64)
            for _ in range(3):                 # cold, warm, warm
                (mean, var, count), rep = (
                    s.scan().map(MeanProgram()).map(VarianceProgram())
                    .map(CountProgram()).reduce().collect())
                rep.query.check_block_invariant()
                rep.query.check_partial_invariant()
                assert int(count) == 40
                np.testing.assert_allclose(np.asarray(mean),
                                           expect.mean(0), atol=1e-4)
                np.testing.assert_allclose(
                    np.asarray(var["var"]), expect.var(0), atol=2e-3)
                tb = s.blocks.tier_bytes()
                assert tb["device"] <= total // 10
                assert tb["host"] <= total // 2
                assert_gauges_exact(s.blocks)
            # warm repeats folded nothing: partials carried the answer
            assert rep.query.rows_folded == 0
            st = s.blocks.stats.snapshot()
            assert st.demotions + st.host_serves > 0

    def test_mutation_under_spill_stays_exact(self, tmpdir):
        t = make_table()
        with GridSession(t, default_eta=4, device_budget=400,
                         host_budget=800, spill_dir=str(tmpdir.join("s")),
                         prefetch=False) as s:
            s.run(MeanProgram())
            s.upload(["a9999"], {
                "img": {"data": np.full((1,) + PAYLOAD, 5.0, np.float32)},
                "idx": {"size": np.array([10_000_000]),
                        "age": np.array([30.0], np.float32),
                        "sex": np.array([1], np.int8)}})
            res, rep = s.run(MeanProgram())
            rep.query.check_block_invariant()
            np.testing.assert_allclose(
                np.asarray(res),
                t.column("img", "data").astype(np.float64).mean(0),
                atol=1e-4)
            assert_gauges_exact(s.blocks)

    def test_auto_spill_dir_created_and_removed(self):
        t = make_table()
        s = GridSession(t, default_eta=4, host_budget=800, prefetch=False)
        try:
            s.run(MeanProgram())
            spill = s.blocks.spill_dir
            assert spill is not None and os.path.isdir(spill)
        finally:
            s.close()
        assert not os.path.isdir(spill)

    def test_partial_budget_spills_partials_not_results(self, tmpdir):
        t = make_table()
        with GridSession(t, default_eta=4, partial_budget=256,
                         spill_dir=str(tmpdir.join("s")),
                         prefetch=False) as s:
            r1, _ = s.run(MeanProgram())
            assert s.blocks.stats.partial_spills > 0
            # plan-result cache cleared: the repeat must reconstruct the
            # answer from (mostly spilled) partials without re-folding
            s._results.clear()
            folds = s.blocks.stats.folds
            r2, rep = s.run(MeanProgram())
            np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
            assert s.blocks.stats.folds == folds
            assert rep.query.rows_folded == 0


# ----------------------------------------------------------------------
# background prefetch
# ----------------------------------------------------------------------

def drain_prefetch(blocks, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        with blocks._lock:
            if not blocks._prefetch_inflight:
                return
        time.sleep(0.005)
    raise AssertionError("prefetch jobs did not drain")


class TestPrefetch:
    def test_promotion_claimed_with_original_classification(self, tmpdir):
        t = make_table()
        with GridSession(t, default_eta=4, device_budget=2 * 512,
                         host_budget=10**6,
                         spill_dir=str(tmpdir.join("s"))) as s:
            s.run(MeanProgram())               # commit + demote most blocks
            # partials (and the plan-result cache) make every region
            # warm; clear both so the next query actually fetches
            # (prefetch skips partial-covered work)
            s.blocks.clear_partials()
            s._results.clear()
            plan = s.scan().map(MeanProgram()).reduce()
            issued = s.prefetch_plan(plan)
            assert issued > 0
            drain_prefetch(s.blocks)
            st = s.blocks.stats.snapshot()
            assert st.prefetches > 0
            res, rep = plan.collect()
            rep.query.check_block_invariant()
            rep.query.check_partial_invariant()
            # the query claimed promoted blocks instead of re-transferring
            assert s.blocks.stats.prefetch_hits > 0
            np.testing.assert_allclose(
                np.asarray(res),
                t.column("img", "data").astype(np.float64).mean(0),
                atol=1e-4)
            drain_prefetch(s.blocks)
            assert_gauges_exact(s.blocks)

    def test_prefetch_never_gathers(self, tmpdir):
        t = make_table()
        with GridSession(t, default_eta=4, device_budget=2 * 512,
                         spill_dir=str(tmpdir.join("s"))) as s:
            plan = s.scan().map(MeanProgram()).reduce()
            # nothing cached yet: promotion-only prefetch must issue ZERO
            # jobs (the table is never read outside a query's own fetch)
            assert s.prefetch_plan(plan) == 0
            assert s.blocks.stats.gathers == 0

    def test_flat_session_prefetch_is_noop(self):
        t = make_table()
        s = GridSession(t, default_eta=4)      # no budgets: no tiering
        plan = s.scan().map(MeanProgram()).reduce()
        assert s.prefetch_plan(plan) == 0
        assert not s.blocks.prefetch_enabled
