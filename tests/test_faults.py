"""Fault injection and self-healing: the injector/retry substrate, the
checksummed spill chain, quarantine + re-home, and the frontend's
dispatch-level retries and circuit breakers.

The two ``slow``-marked subprocess tests are the PR acceptance walks: a
60+ step differential walk under a seeded fault schedule (spill
corruption, transient transfers, one permanent owner loss, stragglers)
that must produce bit-exact results, and a quarantine re-home that must
move every resident payload without a single table re-read.
"""

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.blockstore import BlockStore, LRUCache
from repro.core.chunk_model import TierCostModel
from repro.core.faults import (
    DeviceLostError,
    FaultInjector,
    FaultRule,
    QueryFaultedError,
    RetryPolicy,
    SpillCorruptionError,
    TransientFaultError,
)
from repro.core.frontend import GridFrontend
from repro.core.grid import GridSession, sweep_stale_spill_dirs
from repro.core.stats import CountProgram, MeanProgram, VarianceProgram
from test_grid import make_population

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _subprocess_env(devices=4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


# ----------------------------------------------------------------------
# FaultRule / FaultInjector
# ----------------------------------------------------------------------

class TestFaultRules:
    def test_rule_validation(self):
        with pytest.raises(ValueError):
            FaultRule(site="nope", kind="transient")
        with pytest.raises(ValueError):
            FaultRule(site="gather", kind="nope")
        with pytest.raises(ValueError):
            FaultRule(site="gather", kind="corrupt")   # file kind, dry site
        with pytest.raises(ValueError):
            FaultRule(site="spill_read", kind="device_lost")
        with pytest.raises(ValueError):
            FaultRule(site="gather", kind="transient", p=1.5)

    def test_after_and_times_pin_exact_calls(self):
        inj = FaultInjector(rules=(
            FaultRule(site="gather", kind="transient", after=3, times=2),))
        pattern = []
        for _ in range(8):
            try:
                inj.fire("gather")
                pattern.append(False)
            except TransientFaultError:
                pattern.append(True)
        # skips the first 3 calls, fires exactly twice, then is spent
        assert pattern == [False] * 3 + [True] * 2 + [False] * 3
        assert inj.counts == {"gather:transient": 2}
        assert inj.faults_injected == 2
        assert inj.site_calls("gather") == 8

    def test_probabilistic_schedule_replays_from_seed(self):
        def run(seed):
            inj = FaultInjector(rules=(
                FaultRule(site="device_put", kind="transient", p=0.5),),
                seed=seed)
            out = []
            for _ in range(64):
                try:
                    inj.fire("device_put", device=0)
                    out.append(0)
                except TransientFaultError:
                    out.append(1)
            return out

        a, b = run(11), run(11)
        assert a == b, "same seed must replay bit-for-bit"
        assert 0 < sum(a) < 64, "p=0.5 must fire sometimes, not always"

    def test_device_scoped_rule_ignores_other_devices(self):
        inj = FaultInjector(rules=(
            FaultRule(site="device_put", kind="transient", device=1),))
        inj.fire("device_put", device=0)            # no raise
        with pytest.raises(TransientFaultError):
            inj.fire("device_put", device=1)

    def test_device_loss_is_sticky(self):
        inj = FaultInjector(rules=(
            FaultRule(site="device_put", kind="device_lost", device=1,
                      times=1),))
        with pytest.raises(DeviceLostError) as e:
            inj.fire("device_put", device=1)
        assert e.value.device == 1
        assert inj.lost_devices == {1}
        # the rule is spent (times=1) but the loss is permanent: every
        # later put/fold against the device keeps failing
        for site in ("device_put", "fold"):
            with pytest.raises(DeviceLostError):
                inj.fire(site, device=1)
        inj.fire("device_put", device=0)            # healthy device fine
        assert inj.counts["device_put:device_lost"] == 2

    def test_delay_sleeps_without_raising(self):
        inj = FaultInjector(rules=(
            FaultRule(site="fold", kind="delay", delay_s=0.02),))
        t0 = time.monotonic()
        inj.fire("fold", device=0)
        assert time.monotonic() - t0 >= 0.015
        assert inj.counts == {"fold:delay": 1}

    def test_file_kind_without_file_does_not_count(self, tmpdir):
        inj = FaultInjector(rules=(
            FaultRule(site="spill_read", kind="corrupt"),))
        inj.fire("spill_read", path=str(tmpdir.join("missing.npy")))
        assert inj.faults_injected == 0
        assert inj.counts == {}

    def test_corrupt_flips_bytes_in_place(self, tmpdir):
        path = str(tmpdir.join("x.bin"))
        with open(path, "wb") as f:
            f.write(b"\x00" * 64)
        inj = FaultInjector(rules=(
            FaultRule(site="spill_read", kind="corrupt"),))
        inj.fire("spill_read", path=path)
        data = open(path, "rb").read()
        assert len(data) == 64 and data != b"\x00" * 64
        assert inj.counts == {"spill_read:corrupt": 1}

    def test_on_fire_observer_sees_every_fire(self):
        seen = []
        inj = FaultInjector(rules=(
            FaultRule(site="gather", kind="transient", times=1),))
        inj.on_fire = lambda site, kind: seen.append((site, kind))
        with pytest.raises(TransientFaultError):
            inj.fire("gather")
        inj.fire("gather")
        assert seen == [("gather", "transient")]


# ----------------------------------------------------------------------
# RetryPolicy
# ----------------------------------------------------------------------

class TestRetryPolicy:
    def test_backoff_grows_and_jitter_is_deterministic(self):
        p = RetryPolicy(base_delay_s=1e-3, multiplier=2.0, jitter=0.25)
        assert p.delay_s(2, "k") == p.delay_s(2, "k")
        for a in range(4):
            base = 1e-3 * 2 ** a
            assert 0.75 * base <= p.delay_s(a, "k") <= 1.25 * base
        # jitter de-synchronizes different retriers of the same attempt
        assert p.delay_s(1, "alpha") != p.delay_s(1, "beta")

    def test_call_retries_transients_then_succeeds(self):
        attempts, retries, slept = [], [], []
        def fn():
            attempts.append(1)
            if len(attempts) < 3:
                raise TransientFaultError("flaky")
            return "ok"
        p = RetryPolicy(max_attempts=4, base_delay_s=1e-3)
        out = p.call(fn, key="k",
                     on_retry=lambda e, a: retries.append(a),
                     sleep=slept.append)
        assert out == "ok" and len(attempts) == 3
        assert retries == [1, 2] and len(slept) == 2

    def test_exhaustion_propagates_final_error_unwrapped(self):
        p = RetryPolicy(max_attempts=3, base_delay_s=0.0, jitter=0.0)
        calls = []
        def fn():
            calls.append(1)
            raise TransientFaultError("always")
        with pytest.raises(TransientFaultError):
            p.call(fn, sleep=lambda _s: None)
        assert len(calls) == 3

    def test_permanent_faults_are_not_retried(self):
        p = RetryPolicy(max_attempts=5)
        calls = []
        def fn():
            calls.append(1)
            raise DeviceLostError(2)
        with pytest.raises(DeviceLostError):
            p.call(fn, sleep=lambda _s: None)
        assert len(calls) == 1


# ----------------------------------------------------------------------
# satellite: LRU on_evict hooks that raise
# ----------------------------------------------------------------------

class TestLRUEvictErrors:
    def test_raising_hook_is_counted_and_sweep_continues(self):
        def bomb(_key, _val):
            raise RuntimeError("hook exploded")
        lru = LRUCache(2, on_evict=bomb)
        vals = {k: np.zeros(4, np.float32) for k in "abcd"}
        for k, v in vals.items():
            lru.put(k, v)
        # every eviction fired the raising hook; none aborted the sweep
        assert lru.evict_errors == 2
        assert lru.evictions == 2
        assert set(lru.keys()) == {"c", "d"}

    def test_byte_budget_sweep_survives_raising_hook(self):
        def bomb(_key, _val):
            raise RuntimeError("hook exploded")
        lru = LRUCache(None, max_bytes=64, on_evict=bomb)
        for i in range(6):
            lru.put(i, np.zeros(8, np.float32))    # 32 B each
        assert lru.nbytes <= 64
        assert lru.evict_errors == 4


# ----------------------------------------------------------------------
# checksummed spill: sidecars, atomicity, orphan sweep
# ----------------------------------------------------------------------

class TestChecksummedSpill:
    def test_write_spill_publishes_payload_and_sidecar(self, tmpdir):
        bs = BlockStore(spill_dir=str(tmpdir))
        path = str(tmpdir.join("blk.npy"))
        arr = np.arange(12, dtype=np.float32)
        sz = bs._write_spill(path, lambda f: np.save(f, arr))
        assert sz == os.path.getsize(path)
        assert os.path.exists(path + ".crc")
        bs._verify_spill(path)                     # round-trips clean
        np.testing.assert_array_equal(np.load(path), arr)
        bs.close()

    def test_failed_write_leaves_no_partial_files(self, tmpdir):
        bs = BlockStore(spill_dir=str(tmpdir))
        path = str(tmpdir.join("blk.npy"))
        def writer(f):
            f.write(b"half")
            raise OSError("disk full")
        with pytest.raises(OSError):
            bs._write_spill(path, writer)
        assert os.listdir(str(tmpdir)) == [], "no torn payload/tmp/sidecar"
        bs.close()

    @pytest.mark.parametrize("attack", ["corrupt", "truncate", "delete",
                                        "drop_sidecar"])
    def test_verify_catches_every_mangle(self, tmpdir, attack):
        bs = BlockStore(spill_dir=str(tmpdir))
        path = str(tmpdir.join("blk.npy"))
        bs._write_spill(path, lambda f: np.save(f, np.arange(64.0)))
        if attack == "drop_sidecar":
            os.unlink(path + ".crc")
        else:
            inj = FaultInjector(rules=(
                FaultRule(site="spill_read", kind=attack),))
            inj.fire("spill_read", path=path)
        with pytest.raises(SpillCorruptionError):
            bs._verify_spill(path)
        bs.close()

    def test_startup_sweeps_orphaned_tmp_and_sidecars(self, tmpdir):
        spill = tmpdir.mkdir("spill")
        spill.join("a.npy.tmp").write(b"torn write")
        spill.join("b.npy.crc").write("deadbeef 42\n")   # payload gone
        keep = spill.join("c.npy")
        keep.write(b"payload")
        spill.join("c.npy.crc").write("cafebabe 7\n")
        bs = BlockStore(spill_dir=str(spill))
        assert bs.orphans_swept == 2
        assert sorted(os.listdir(str(spill))) == ["c.npy", "c.npy.crc"]
        bs.close()


class TestStaleSpillDirSweep:
    def test_dead_session_dirs_are_reaped_live_kept(self, tmpdir):
        root = str(tmpdir)
        proc = subprocess.Popen(["sleep", "0"])
        proc.wait()
        dead_pid = proc.pid          # reaped: os.kill(pid, 0) now fails
        os.makedirs(os.path.join(root, f"grid-spill-{dead_pid}-ab12"))
        live = os.path.join(root, f"grid-spill-{os.getpid()}-cd34")
        os.makedirs(live)
        unrelated = os.path.join(root, "grid-spill-not-a-pid")
        os.makedirs(unrelated)
        assert sweep_stale_spill_dirs(root) == 1
        assert os.path.isdir(live), "our own spill dir must survive"
        assert os.path.isdir(unrelated), "non-matching names untouched"

    def test_session_close_removes_owned_spill_dir(self, tmpdir):
        spill = str(tmpdir.join("owned"))
        s = GridSession(make_population(16), device_budget=0,
                        host_budget=0, spill_dir=spill, prefetch=False)
        s.run(MeanProgram())
        assert os.path.isdir(spill)
        s.close()
        assert not os.path.exists(spill)


# ----------------------------------------------------------------------
# recovery through the session stack
# ----------------------------------------------------------------------

class TestSpillRecovery:
    def _disk_session(self, tmpdir, **kw):
        """Every payload block rides the disk tier: no device, no host."""
        kw.setdefault("device_budget", 0)
        kw.setdefault("host_budget", 0)
        return GridSession(make_population(32), default_eta=8,
                           spill_dir=str(tmpdir.join("spill")),
                           prefetch=False, **kw)

    def test_corrupted_block_spill_rederives_losslessly(self, tmpdir):
        s = self._disk_session(tmpdir)
        expect = s.table.column("img", "data").mean(axis=0)
        res, _ = s.run(MeanProgram())
        np.testing.assert_allclose(np.asarray(res), expect, atol=1e-5)
        spill = str(tmpdir.join("spill"))
        payloads = [f for f in os.listdir(spill) if f.endswith(".npy")]
        assert payloads, "blocks must have spilled to disk"
        for f in payloads:     # flip bytes in EVERY spilled block
            p = os.path.join(spill, f)
            with open(p, "r+b") as fh:
                fh.seek(os.path.getsize(p) // 2)
                fh.write(b"\xff\xff\xff\xff")
        res2, _ = s.run(VarianceProgram())
        np.testing.assert_allclose(np.asarray(res2["var"]),
                                   s.table.column("img", "data").var(axis=0),
                                   atol=1e-4)
        st = s.blocks.stats.snapshot()
        assert st.spill_corruptions >= len(payloads)
        assert st.spill_recoveries >= len(payloads)
        s.close()

    def test_deleted_block_spill_rederives_losslessly(self, tmpdir):
        inj = FaultInjector(rules=(
            FaultRule(site="spill_read", kind="delete", times=2),))
        s = self._disk_session(tmpdir, fault_injector=inj)
        s.run(MeanProgram())
        res, _ = s.run(VarianceProgram())
        np.testing.assert_allclose(np.asarray(res["var"]),
                                   s.table.column("img", "data").var(axis=0),
                                   atol=1e-4)
        st = s.blocks.stats.snapshot()
        assert st.spill_corruptions >= 1
        assert st.spill_recoveries >= 1
        assert st.faults_injected == inj.faults_injected > 0
        s.close()

    def test_corrupted_partial_spill_refolds_exactly(self, tmpdir):
        inj = FaultInjector(rules=(
            FaultRule(site="spill_read", kind="corrupt", times=1),))
        s = GridSession(make_population(32), default_eta=8,
                        partial_budget=1,
                        spill_dir=str(tmpdir.join("spill")),
                        prefetch=False, fault_injector=inj)
        res, _ = s.run(MeanProgram())
        # drop the finalized-result cache so the repeat must re-assemble
        # from partials: it reads the spilled partial back, the injected
        # flip is caught by the CRC, and the partial silently refolds
        s._results.clear()
        res2, _ = s.run(MeanProgram())
        np.testing.assert_array_equal(np.asarray(res), np.asarray(res2))
        assert s.blocks.stats.spill_corruptions >= 1
        s.close()

    def test_transient_device_put_retries_then_serves(self):
        inj = FaultInjector(rules=(
            FaultRule(site="device_put", kind="transient", times=2),))
        s = GridSession(make_population(32), default_eta=8,
                        fault_injector=inj,
                        retry_policy=RetryPolicy(max_attempts=4,
                                                 base_delay_s=1e-5))
        res, _ = s.run(MeanProgram())
        np.testing.assert_allclose(np.asarray(res),
                                   s.table.column("img", "data").mean(axis=0),
                                   atol=1e-5)
        st = s.blocks.stats.snapshot()
        assert st.retries >= 1
        assert st.faults_injected == 2
        s.close()

    def test_exhausted_transients_degrade_to_host_serving(self):
        # EVERY device_put fails: blocks can never commit to the device,
        # so queries must fall back to host-resident folding — correct
        # results, zero crashes
        inj = FaultInjector(rules=(
            FaultRule(site="device_put", kind="transient", p=1.0),))
        s = GridSession(make_population(32), default_eta=8,
                        fault_injector=inj,
                        retry_policy=RetryPolicy(max_attempts=2,
                                                 base_delay_s=1e-5))
        res, _ = s.run(MeanProgram())
        np.testing.assert_allclose(np.asarray(res),
                                   s.table.column("img", "data").mean(axis=0),
                                   atol=1e-5)
        st = s.blocks.stats.snapshot()
        assert st.transfers == 0, "nothing can have committed to the device"
        assert st.device_bytes == 0
        assert st.retries >= 1 and st.faults_injected >= 2
        s.close()


class TestQuarantine:
    def test_single_device_loss_degrades_to_host(self):
        s = GridSession(make_population(32), default_eta=8,
                        fault_injector=FaultInjector())
        s.run(MeanProgram())
        s.faults.lost_devices.add(0)       # the only device dies
        res, _ = s.run(VarianceProgram())  # new program: must re-fold
        np.testing.assert_allclose(np.asarray(res["var"]),
                                   s.table.column("img", "data").var(axis=0),
                                   atol=1e-4)
        assert s.quarantined_devices == frozenset({0})
        assert s.blocks.stats.quarantines == 1
        res2, _ = s.run(CountProgram())    # keeps serving afterwards
        assert int(np.asarray(res2)) == 32
        s.close()


# ----------------------------------------------------------------------
# fault-adjusted tier costs
# ----------------------------------------------------------------------

class TestFaultAdjustedCosts:
    def test_zero_rate_collapses_to_plain_refetch(self):
        m = TierCostModel()
        assert m.expected_attempts() == 1.0
        assert m.expected_refetch_s(1 << 20) == m.refetch_s(1 << 20)

    def test_capped_geometric_attempts(self):
        import dataclasses
        m = dataclasses.replace(TierCostModel(), refetch_fault_rate=0.5,
                                max_refetch_attempts=3)
        assert m.expected_attempts() == pytest.approx((1 - 0.5 ** 3) / 0.5)

    def test_fault_rate_inflates_refetch_and_biases_toward_spill(self):
        import dataclasses
        m0 = TierCostModel()
        m1 = dataclasses.replace(m0, refetch_fault_rate=0.9,
                                 retry_backoff_s=0.01)
        n = 1 << 22
        assert m1.expected_refetch_s(n) > m0.expected_refetch_s(n)
        # spilling can only become MORE attractive as the fabric flakes
        for nbytes in (1 << 12, 1 << 20, 1 << 26):
            if m0.should_spill_block(nbytes):
                assert m1.should_spill_block(nbytes)


# ----------------------------------------------------------------------
# frontend: dispatch retries, QueryFaultedError, circuit breakers
# ----------------------------------------------------------------------

def _frontend_session(**kw):
    return GridSession(make_population(32), default_eta=8, **kw)


class TestFrontendFaults:
    def test_dispatch_transient_retries_then_serves(self):
        inj = FaultInjector(rules=(
            FaultRule(site="dispatch", kind="transient", times=1),))
        s = _frontend_session(fault_injector=inj)
        with GridFrontend(s, tick_ms=0,
                          retry_policy=RetryPolicy(max_attempts=3,
                                                   base_delay_s=1e-4)) as fe:
            res, _ = fe.query(s.scan().map(MeanProgram()).reduce(),
                              timeout=60)
            stats = fe.stats.snapshot()
        np.testing.assert_allclose(np.asarray(res),
                                   s.table.column("img", "data").mean(axis=0),
                                   atol=1e-5)
        assert stats.retries == 1 and stats.faults == 1
        assert stats.served == 1 and stats.failed == 0
        s.close()

    def test_exhausted_retries_raise_query_faulted_with_chain(self):
        inj = FaultInjector(rules=(
            FaultRule(site="dispatch", kind="transient", p=1.0),))
        s = _frontend_session(fault_injector=inj)
        with GridFrontend(s, tick_ms=0, coalesce=False,
                          retry_policy=RetryPolicy(max_attempts=3,
                                                   base_delay_s=1e-4),
                          breaker_threshold=0) as fe:
            with pytest.raises(QueryFaultedError) as e:
                fe.query(s.scan().map(MeanProgram()).reduce(), timeout=60)
            stats = fe.stats.snapshot()
        assert len(e.value.chain) == 3
        assert all(isinstance(c, TransientFaultError) for c in e.value.chain)
        assert "TransientFaultError" in e.value.describe()
        assert stats.failed == 1 and stats.faults == 3 and stats.retries == 2
        s.close()

    def test_breaker_opens_after_threshold_and_fast_fails(self):
        inj = FaultInjector(rules=(
            FaultRule(site="dispatch", kind="transient", p=1.0),))
        s = _frontend_session(fault_injector=inj)
        plan = s.scan().map(MeanProgram()).reduce()
        with GridFrontend(s, tick_ms=0, coalesce=False,
                          retry_policy=RetryPolicy(max_attempts=2,
                                                   base_delay_s=1e-4),
                          breaker_threshold=2,
                          breaker_cooldown_s=30.0) as fe:
            for _ in range(2):
                with pytest.raises(QueryFaultedError):
                    fe.query(plan, timeout=60)
            stats_mid = fe.stats.snapshot()
            # breaker now open: submission fails synchronously, without
            # touching the executor
            with pytest.raises(QueryFaultedError) as e:
                fe.submit(plan)
            stats = fe.stats.snapshot()
        assert "circuit breaker open" in str(e.value)
        assert stats_mid.breaker_opens == 1
        assert stats.rejected == 1
        assert stats.submitted == 2, "fast-fail must not count a submission"
        s.close()

    def test_breaker_cooldown_lets_probe_through(self):
        inj = FaultInjector(rules=(
            FaultRule(site="dispatch", kind="transient", times=4),))
        s = _frontend_session(fault_injector=inj)
        plan = s.scan().map(MeanProgram()).reduce()
        with GridFrontend(s, tick_ms=0, coalesce=False,
                          retry_policy=RetryPolicy(max_attempts=2,
                                                   base_delay_s=1e-4),
                          breaker_threshold=2,
                          breaker_cooldown_s=0.05) as fe:
            for _ in range(2):
                with pytest.raises(QueryFaultedError):
                    fe.query(plan, timeout=60)
            time.sleep(0.1)     # cooldown expires; the schedule is spent
            res, _ = fe.query(plan, timeout=60)
            stats = fe.stats.snapshot()
        np.testing.assert_allclose(np.asarray(res),
                                   s.table.column("img", "data").mean(axis=0),
                                   atol=1e-5)
        assert stats.served == 1
        s.close()

    def test_success_resets_breaker_failure_count(self):
        # fail once, succeed once, fail once: threshold=2 must NOT trip
        inj = FaultInjector(rules=(
            FaultRule(site="dispatch", kind="transient", times=1),
            FaultRule(site="dispatch", kind="transient", after=2, times=1),))
        s = _frontend_session(fault_injector=inj)
        plan = s.scan().map(MeanProgram()).reduce()
        with GridFrontend(s, tick_ms=0, coalesce=False,
                          retry_policy=RetryPolicy(max_attempts=1,
                                                   base_delay_s=1e-4),
                          breaker_threshold=2,
                          breaker_cooldown_s=30.0) as fe:
            with pytest.raises(QueryFaultedError):
                fe.query(plan, timeout=60)
            fe.query(plan, timeout=60)          # success: counter resets
            with pytest.raises(QueryFaultedError):
                fe.query(plan, timeout=60)
            fe.query(plan, timeout=60)          # breaker never opened
            stats = fe.stats.snapshot()
        assert stats.breaker_opens == 0
        s.close()


# ----------------------------------------------------------------------
# acceptance walks (multi-device, subprocess)
# ----------------------------------------------------------------------

@pytest.mark.slow
class TestAcceptanceWalks:
    def test_differential_walk_with_owner_loss_4dev(self):
        """60+ interleaved steps under the full fault mix — including one
        PERMANENT owner loss mid-walk — with bit-exact oracle agreement,
        recount-exact gauges, >= 1 spill recovery, and a quarantine that
        re-homed the dead owner's regions."""
        body = """
            import numpy as np
            from repro.core.balancer import NodeSpec
            from repro.core.faults import FaultInjector, FaultRule, RetryPolicy
            from test_differential import (DifferentialDriver,
                                           FaultWalkDriver, fault_walk_rules)

            rules = fault_walk_rules() + (
                FaultRule(site="device_put", kind="device_lost", device=2,
                          after=15, times=1),)
            inj = FaultInjector(rules=rules, seed=5)
            import tempfile
            drv = FaultWalkDriver(session_kwargs=dict(
                nodes=[NodeSpec(i, cores=1, mips=1.0) for i in range(4)],
                device_budget=4096, host_budget=256, partial_budget=512,
                disk_budget=1 << 20,
                spill_dir=tempfile.mkdtemp(prefix="fault-walk-"),
                prefetch=False, fault_injector=inj,
                retry_policy=RetryPolicy(max_attempts=4, base_delay_s=1e-4)))
            rng = np.random.default_rng(5)
            ops = list(DifferentialDriver.OPS)
            w = np.array([4, 2, 2, 1, 1, 2, 3, 2, 2, 2, 1], dtype=float)
            w /= w.sum()
            for _ in range(80):
                drv.apply(str(rng.choice(ops, p=w)),
                          int(rng.integers(0, 2**31)))
            s = drv.session.blocks.stats.snapshot()
            assert s.faults_injected == inj.faults_injected > 0, s
            assert s.spill_recoveries >= 1, s
            assert s.retries >= 1, s
            assert s.quarantines >= 1, s
            assert 2 in drv.session.quarantined_devices
            assert 2 in inj.lost_devices
            drv.session.close()
            print("FAULT_WALK_OK", s.faults_injected, s.spill_recoveries,
                  s.quarantines)
        """
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(body)],
            capture_output=True, text=True, env=_subprocess_env(4),
            timeout=600)
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
        assert "FAULT_WALK_OK" in proc.stdout

    def test_quarantine_rehomes_without_table_rereads_4dev(self):
        """A permanent owner loss re-homes the dead node's regions through
        the balancer; every resident payload moves as a cached host copy —
        ZERO table re-reads — and serving continues exactly."""
        body = """
            import numpy as np
            from repro.core.balancer import NodeSpec
            from repro.core.faults import FaultInjector
            from repro.core.grid import GridSession
            from repro.core.stats import (CountProgram, MeanProgram,
                                          VarianceProgram)
            from test_grid import make_population

            t = make_population(128, split_bytes=int(50e6))
            inj = FaultInjector()
            s = GridSession(t, default_eta=8, fault_injector=inj,
                            nodes=[NodeSpec(i, cores=1, mips=1.0)
                                   for i in range(4)])
            s.run(MeanProgram())                       # warm every owner
            assert len(set(s.placement.alloc.values())) > 1
            inj.lost_devices.add(2)                    # owner 2 dies, hard
            gathers0 = s.blocks.stats.gathers
            res, _ = s.run(VarianceProgram())          # trips the loss
            np.testing.assert_allclose(
                np.asarray(res["var"]),
                t.column("img", "data").var(axis=0), atol=1e-4)
            assert s.blocks.stats.quarantines == 1
            assert s.quarantined_devices == frozenset({2})
            # the dead node owns nothing after the re-home
            homes = {s.placement.alloc[r.rid] for r in t.regions}
            assert 2 not in {s._node_index.get(h) for h in homes}
            # a fresh program folds on the NEW owners: cached host copies
            # ship over, the table is never re-read
            res2, rep2 = s.run(CountProgram())
            assert int(np.asarray(res2)) == 128
            q2 = rep2.query
            assert s.blocks.stats.gathers == gathers0, "zero table re-reads"
            assert q2.blocks_transferred > 0, q2
            print("REHOME_OK", s.blocks.stats.quarantines,
                  q2.blocks_transferred)
        """
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(body)],
            capture_output=True, text=True, env=_subprocess_env(4),
            timeout=600)
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
        assert "REHOME_OK" in proc.stdout
