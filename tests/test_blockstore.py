"""BlockStore: copy-on-write per-region device blocks shared across epochs
and plans.

The two PR acceptance oracles live here: (1) after ``session.remove`` of one
region, a repeat ``.stats()`` re-transfers ONLY that region's blocks — every
other region's device block is the *same object* (no re-pad, no re-
``device_put``); (2) two overlapping pruned scans share gathered blocks — the
second plan's ``gather_count`` counts only blocks the first didn't gather.
Plus the LRU cap regressions: eviction + loss-free re-materialization for
both the block cache and the bound-plan cache.
"""

import numpy as np
import pytest

from repro.core.blockstore import BlockStore, LRUCache
from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate
from repro.core.regions import HierarchicalSplitPolicy, Region
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

PAYLOAD = (3, 4)


def make_table(groups=("a", "b", "c", "d", "e"), per=8, seed=0):
    """One presplit region per rowkey prefix, ``per`` rows each."""
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=list(groups)[1:],
    )
    keys = [f"{g}{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8)}})
    return t


def batch(keys, seed=1):
    rng = np.random.default_rng(seed)
    n = len(keys)
    return {"img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
            "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                    "age": rng.uniform(4, 80, n).astype(np.float32),
                    "sex": rng.integers(0, 2, n).astype(np.int8)}}


# ----------------------------------------------------------------------
# LRUCache / BlockStore units
# ----------------------------------------------------------------------

class TestLRUCache:
    def test_eviction_order_and_counter(self):
        evicted = []
        c = LRUCache(2, on_evict=lambda k, v: evicted.append(k))
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refreshes 'a': 'b' is now coldest
        c.put("c", 3)
        assert "b" not in c and "a" in c and "c" in c
        assert evicted == ["b"] and c.evictions == 1

    def test_peek_does_not_refresh(self):
        c = LRUCache(2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.peek("a") == 1         # no recency bump: 'a' still coldest
        c.put("c", 3)
        assert "a" not in c

    def test_cap_semantics(self):
        # negative caps are errors; 0 disables; None is unbounded
        with pytest.raises(ValueError):
            LRUCache(-1)
        with pytest.raises(ValueError):
            LRUCache(None, max_bytes=-1)
        disabled = LRUCache(0)
        assert not disabled.put("a", 1)
        assert len(disabled) == 0 and disabled.evictions == 1
        unbounded = LRUCache(None)
        for i in range(10_000):
            unbounded.put(i, i)
        assert len(unbounded) == 10_000 and unbounded.evictions == 0

    def test_byte_budget_evicts_before_insert(self):
        c = LRUCache(None, max_bytes=100, weigher=lambda v: v)
        assert c.put("a", 60) and c.put("b", 30)
        assert c.nbytes == 90
        # inserting 30 must evict 'a' FIRST (never 120 bytes resident)
        assert c.put("c", 30)
        assert "a" not in c and c.nbytes == 60

    def test_oversized_entry_never_admitted(self):
        evicted = []
        c = LRUCache(None, max_bytes=100, weigher=lambda v: v,
                     on_evict=lambda k, v: evicted.append(k))
        c.put("cold", 40)
        assert not c.put("huge", 500)
        # the oversized entry is reported evicted; the colder resident
        # survives untouched
        assert evicted == ["huge"]
        assert "cold" in c and c.nbytes == 40

    def test_replace_preserves_recency(self):
        c = LRUCache(2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.replace("a", 10)       # 'a' stays coldest
        c.put("c", 3)
        assert "a" not in c and c.peek("b") == 2
        assert not c.replace("zz", 0)   # absent keys are not inserted
        assert "zz" not in c


class TestBlockStoreVersions:
    def region(self, rid=1):
        return Region(rid, b"a", b"b")

    def fetch(self, store, region, value=1.0):
        return store.fetch(
            region, "img", "data", owner_index=None,
            gather_host=lambda: np.full((4, 2), value, np.float32),
            to_device=None)

    def test_touch_bumps_version_and_drops_superseded(self):
        store = BlockStore(cap=8)
        r = self.region()
        assert store.version_of(r.rid) == 0
        blk1, reused, gathered = self.fetch(store, r)
        assert gathered and not reused
        blk2, reused, gathered = self.fetch(store, r)
        # host-only mode: the content hit skips the table re-read but every
        # fetch still counts as a transfer (the fallback re-ships layouts)
        assert blk2 is blk1 and not gathered and not reused
        store.touch([r.rid], epoch=3)
        assert store.version_of(r.rid) == 3
        assert store.peek(r, "img", "data") is None   # superseded key gone
        blk3, reused, gathered = self.fetch(store, r, value=2.0)
        assert gathered and not reused and blk3 is not blk1
        # copy-on-write: the old object survives for holders, unmodified
        assert float(blk1.host[0, 0]) == 1.0

    def test_lineage_signature(self):
        store = BlockStore(cap=8)
        regs = [Region(1, b"", b"m"), Region(2, b"m", None)]
        assert store.lineage(regs) == ((1, 0), (2, 0))
        store.touch([2], epoch=5)
        assert store.lineage(regs) == ((1, 0), (2, 5))

    def test_block_host_is_immutable(self):
        store = BlockStore(cap=8)
        blk, _, _ = self.fetch(store, self.region())
        with pytest.raises(ValueError):
            blk.host[0, 0] = 9.0


# ----------------------------------------------------------------------
# acceptance oracle 1: remove re-transfers only the touched region
# ----------------------------------------------------------------------

class TestRemoveReusesCleanBlocks:
    def test_repeat_stats_after_remove_retransfers_one_region(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        q = s.scan().map(MeanProgram())
        rep1 = q.stats()
        R = len(t.regions)
        assert rep1.query.blocks_total == R == 5
        assert rep1.query.blocks_transferred == R    # cold store
        assert rep1.query.gather_count == R
        rep1.query.check_block_invariant()

        before = {r.rid: s.blocks.peek(r, "img", "data") for r in t.regions}
        assert all(b is not None for b in before.values())

        doomed = b"c0000"
        assert s.remove(rowkey=doomed) == 1
        rep2 = q.stats()
        # the acceptance criterion: blocks_reused >= regions - 1
        assert rep2.query.blocks_total == R
        assert rep2.query.blocks_reused == R - 1
        assert rep2.query.blocks_transferred == 1
        assert rep2.query.gather_count == 1
        rep2.query.check_block_invariant()

        # block identity: every untouched region's block — host AND device
        # arrays — is the SAME object; only the removed row's region re-made
        for r in t.regions:
            blk = s.blocks.peek(r, "img", "data")
            if r.contains(doomed):
                assert blk is not before[r.rid]
                assert blk.rows == before[r.rid].rows - 1
            else:
                assert blk is before[r.rid]
                assert blk.device is before[r.rid].device
                assert blk.host is before[r.rid].host

        np.testing.assert_allclose(
            np.asarray(q.collect()[0]), t.column("img", "data").mean(0),
            atol=1e-5)

    def test_upload_into_one_region_keeps_other_blocks(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        before = {r.rid: s.blocks.peek(r, "img", "data") for r in t.regions}
        s.upload(["d9999"], batch(["d9999"], seed=7))
        _, rep = s.run(MeanProgram())
        assert rep.query.blocks_reused == len(t.regions) - 1
        for r in t.regions:
            blk = s.blocks.peek(r, "img", "data")
            if r.contains(b"d9999"):
                assert blk is not before[r.rid]
            else:
                assert blk is before[r.rid]


# ----------------------------------------------------------------------
# acceptance oracle 2: overlapping pruned scans share gathered blocks
# ----------------------------------------------------------------------

class TestOverlappingScansShareBlocks:
    def test_second_plan_gathers_only_new_blocks(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        data = t.column("img", "data")

        ra = s.scan(start="a", stop="c").map(MeanProgram()).stats()
        assert ra.query.regions_scanned == 2          # regions a, b
        assert ra.query.gather_count == 2
        ra.query.check_block_invariant()
        region_b = t.regions.region_for(b"b0000")
        shared = s.blocks.peek(region_b, "img", "data")

        rb = s.scan(start="b", stop="e").map(MeanProgram()).stats()
        assert rb.query.regions_scanned == 3          # regions b, c, d
        assert rb.query.blocks_total == 3
        assert rb.query.blocks_reused == 1            # b, from plan A
        assert rb.query.gather_count == 2             # only c and d
        rb.query.check_block_invariant()
        assert s.blocks.peek(region_b, "img", "data") is shared

        lo, hi = t.row_range(b"b", b"e")
        res, _ = s.scan(start="b", stop="e").map(MeanProgram()).collect()
        np.testing.assert_allclose(np.asarray(res), data[lo:hi].mean(0),
                                   atol=1e-5)

    def test_different_predicates_share_the_same_blocks(self):
        t = make_table(per=16, seed=3)
        s = GridSession(t, default_eta=4)
        p1 = age_sex_predicate(20, 40, None)
        p2 = age_sex_predicate(40, 70, 0)
        r1 = (s.scan(prefix="b").where(p1, ["age", "sex"])
              .map(MeanProgram()).stats())
        assert r1.query.gather_count == 1
        r2 = (s.scan(prefix="b").where(p2, ["age", "sex"])
              .map(MeanProgram()).stats())
        # same region subset, different predicate: zero new gathers
        assert r2.query.gather_count == 0
        assert r2.query.blocks_reused == r2.query.blocks_total == 1
        mask = p2({"age": t.column("idx", "age"),
                   "sex": t.column("idx", "sex")})
        mask &= np.char.startswith(t.keys.astype("S1"), b"b")
        if mask.any():
            res, _ = (s.scan(prefix="b").where(p2, ["age", "sex"])
                      .map(MeanProgram()).collect())
            np.testing.assert_allclose(
                np.asarray(res), t.column("img", "data")[mask].mean(0),
                atol=1e-5)

    def test_scan_plan_survives_unrelated_mutation(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        q = s.scan(prefix="a").map(MeanProgram())
        r1 = q.stats()
        assert not r1.plan_cache_hit
        s.remove(rowkey=b"e0000")       # touches only region e
        r2 = q.stats()                  # epoch changed -> memo miss, BUT
        assert r2.plan_cache_hit        # lineage of region a is unchanged
        assert r2.query.blocks_reused == r2.query.blocks_total
        assert r2.query.gather_count == 0
        # a split-free upload elsewhere doesn't bump placement.version, so
        # the bound plan keeps surviving across upload epochs too
        s.upload(["e9999"], batch(["e9999"], seed=13))
        r3 = q.stats()
        assert r3.plan_cache_hit
        np.testing.assert_allclose(
            np.asarray(q.collect()[0]),
            t.column("img", "data")[:8].mean(0), atol=1e-5)


class TestStaleStateReleased:
    def test_split_parent_blocks_are_dropped(self):
        t = make_table(groups=("a", "b"), per=8)
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())                   # blocks for both regions
        # shrink the split threshold so the next upload splits region b
        t.split_policy.max_region_bytes = int(40e6)
        t.regions.policy.max_region_bytes = int(40e6)
        keys = [f"b9{i:03d}" for i in range(8)]
        regions_before = len(t.regions)
        s.upload(keys, batch(keys, seed=11))
        assert len(t.regions) > regions_before, "upload must have split"
        live = {r.rid for r in t.regions}
        stored = {k[0][0] for k in s.blocks._blocks.keys()}
        assert stored <= live, "split parents' blocks must be forgotten"
        # cached results spanning the split parent are keyed on its dead
        # lineage — they must be evicted eagerly, not ride the LRU to TTL
        for entry in s._results.values():
            assert entry.region_ids <= live, \
                "split parents' results must be forgotten"
        res, rep = s.run(MeanProgram())
        rep.query.check_block_invariant()
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data").mean(0), atol=1e-5)

    def test_dead_results_evicted_on_their_regions_mutation(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.scan(prefix="b").map(MeanProgram()).stats()
        s.scan(prefix="d").map(MeanProgram()).stats()
        assert len(s._results) == 2
        s.remove(rowkey=b"b0000")       # kills ONLY the b-result's lineage
        assert len(s._results) == 1
        s.remove(rowkey=b"d0000")
        assert len(s._results) == 0


# ----------------------------------------------------------------------
# LRU caps: eviction + loss-free re-materialization
# ----------------------------------------------------------------------

class TestCacheCaps:
    def test_block_cache_eviction_rematerializes(self):
        t = make_table()                       # 5 regions
        s = GridSession(t, default_eta=4, block_cache_cap=2)
        res, rep = s.run(MeanProgram())
        assert s.blocks.evictions >= 3         # 5 blocks through a 2-cap
        assert len(s.blocks) <= 2
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data").mean(0), atol=1e-5)
        # mutate, then rebuild: evicted blocks re-gather losslessly
        s.upload(["a9999"], batch(["a9999"], seed=5))
        res2, rep2 = s.run(MeanProgram())
        rep2.query.check_block_invariant()
        assert rep2.query.gather_count >= 1
        np.testing.assert_allclose(
            np.asarray(res2), t.column("img", "data").mean(0), atol=1e-5)

    def test_plan_cache_eviction_rematerializes_without_regather(self):
        t = make_table()
        s = GridSession(t, default_eta=4, plan_cache_cap=1)
        qa = s.scan(prefix="a").map(MeanProgram())
        qb = s.scan(prefix="b").map(MeanProgram())
        qa.stats()
        qb.stats()                       # evicts qa's bound plan
        misses = s.metrics.plan_misses
        r = s.scan(prefix="a").map(MeanProgram()).stats()
        assert not r.plan_cache_hit
        assert s.metrics.plan_misses == misses + 1
        # the PLAN re-binds, but its blocks are still store-resident
        assert r.query.gather_count == 0
        assert r.query.blocks_reused == r.query.blocks_total == 1
        np.testing.assert_allclose(
            np.asarray(s.scan(prefix="a").map(MeanProgram()).collect()[0]),
            t.column("img", "data")[:8].mean(0), atol=1e-5)

    def test_caps_are_configurable(self):
        s = GridSession(make_table(), plan_cache_cap=7, block_cache_cap=11,
                        partial_cache_cap=13)
        assert s._results.cap == 7
        assert s.blocks.cap == 11
        assert s.blocks._partials.cap == 13

    def test_engine_executable_cache_is_bounded(self):
        t = make_table(per=4)
        s = GridSession(t, default_eta=4)
        s.engine._compiled.cap = 1
        s.run(MeanProgram())
        c1 = s.engine.compile_count
        s.run(VarianceProgram())         # evicts the mean executable
        s.run(MeanProgram())
        assert s.engine.compile_count >= c1 + 1  # recompiled after evict
        np.testing.assert_allclose(
            np.asarray(s.run(MeanProgram())[0]),
            t.column("img", "data").mean(0), atol=1e-5)


# ----------------------------------------------------------------------
# rebalance re-homes blocks without re-reading the table (multi-node)
# ----------------------------------------------------------------------

class TestRebalanceRehomesBlocks:
    def test_rebalance_moves_blocks_not_bytes_4dev(self):
        import os
        import subprocess
        import sys
        import textwrap
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(repo, "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        body = """
            import numpy as np
            from repro.core.balancer import NodeSpec
            from repro.core.grid import GridSession
            from repro.core.regions import HierarchicalSplitPolicy
            from repro.core.stats import MeanProgram, VarianceProgram
            from repro.core.table import make_mip_table

            rng = np.random.default_rng(0)
            t = make_mip_table(
                payload_shape=(2,),
                split_policy=HierarchicalSplitPolicy(max_region_bytes=int(50e6)))
            n = 128
            t.upload([f"r{i:05d}" for i in range(n)],
                     {"img": {"data": rng.normal(size=(n, 2)).astype(np.float32)},
                      "idx": {"size": rng.integers(6e6, 2e7, n)}})
            s = GridSession(t, nodes=[NodeSpec(i, cores=1, mips=1.0)
                                      for i in range(4)])
            s.run(MeanProgram())
            # skew powers so the balancer must move regions
            moved = s.rebalance(nodes=[NodeSpec(0, cores=1, mips=4.0)]
                                + [NodeSpec(i, cores=1, mips=1.0)
                                   for i in range(1, 4)],
                                tolerance=0.01)
            assert moved, "power skew must force region moves"
            res, rep = s.run(MeanProgram())
            q = rep.query
            # fold partials are placement-independent: the repeat query
            # after the move folds nothing and ships nothing at all
            assert q.rows_folded == 0, q
            assert q.partials_reused == q.partials_total, q
            assert q.gather_count == 0 and q.blocks_transferred == 0, q
            np.testing.assert_allclose(np.asarray(res),
                                       t.column("img", "data").mean(0),
                                       atol=1e-5)
            # a NEW program must fold, so it needs the blocks: moved
            # regions re-ship their cached host copies to the new owners;
            # NOTHING is re-read from the table (content untouched)
            res2, rep2 = s.run(VarianceProgram())
            q2 = rep2.query
            assert q2.gather_count == 0, q2
            assert q2.blocks_transferred == len(moved), (q2, moved)
            assert q2.blocks_reused == q2.blocks_total - len(moved), q2
            np.testing.assert_allclose(np.asarray(res2["var"]),
                                       t.column("img", "data").var(0),
                                       atol=1e-4)
            print("REBALANCE_BLOCKS_OK", len(moved))
        """
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(body)],
            capture_output=True, text=True, env=env, timeout=600)
        assert proc.returncode == 0, (
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
        assert "REBALANCE_BLOCKS_OK" in proc.stdout
