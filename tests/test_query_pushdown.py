"""Query byte accounting + pushdown correctness (§2.3 unified with §2.2).

The table-scheme claims the seed only asserted by hand in examples:
``indexed_query`` touches strictly fewer bytes than ``naive_query`` for the
same predicate while returning the identical mask, and the GridSession
pushdown path (``run_where``) equals filter-then-run for every stats
program, at every chunk size η, moving only the selected rows' payload.
"""

import numpy as np
import pytest

from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate, indexed_query, naive_query
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    HistogramProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table, make_naive_table

PAYLOAD = (5, 4)
N = 119  # deliberately not a chunk multiple


@pytest.fixture(scope="module")
def tables():
    rng = np.random.default_rng(7)
    data = rng.normal(size=(N,) + PAYLOAD).astype(np.float32)
    ages = rng.uniform(4, 80, N).astype(np.float32)
    sexes = rng.integers(0, 2, N).astype(np.int8)
    sizes = rng.integers(6_000_000, 20_000_001, N)
    idx_cols = [ColumnSpec("age", (), np.float32),
                ColumnSpec("sex", (), np.int8)]
    prop = make_mip_table(
        payload_shape=PAYLOAD, extra_index_columns=idx_cols,
        split_policy=HierarchicalSplitPolicy(max_region_bytes=400_000_000))
    prop.upload([f"img{i:05d}" for i in range(N)],
                {"img": {"data": data},
                 "idx": {"size": sizes, "age": ages, "sex": sexes}})
    naive = make_naive_table(payload_shape=PAYLOAD,
                             extra_index_columns=idx_cols)
    naive.upload([f"img{i:05d}" for i in range(N)],
                 {"img": {"data": data, "size": sizes,
                          "age": ages, "sex": sexes}})
    return prop, naive, data


PREDICATES = [
    ("female 20-40", age_sex_predicate(20, 40, 1)),
    ("male >60", age_sex_predicate(60, None, 0)),
    ("all", age_sex_predicate(None, None, None)),
    ("empty", age_sex_predicate(200, 300, 1)),
]


class TestByteAccounting:
    @pytest.mark.parametrize("name,pred", PREDICATES)
    def test_identical_masks_fewer_bytes(self, tables, name, pred):
        prop, naive, _ = tables
        m_p, st_p = indexed_query(prop, pred, ["age", "sex"])
        m_n, st_n = naive_query(naive, pred, ["age", "sex"])
        np.testing.assert_array_equal(m_p, m_n)
        assert st_p.payload_bytes_traversed == 0
        assert st_n.payload_bytes_traversed > 0
        assert st_p.total_bytes_scanned < st_n.total_bytes_scanned

    def test_index_bytes_match_schema(self, tables):
        prop, _, _ = tables
        _, st = indexed_query(prop, age_sex_predicate(20, 40, 1),
                              ["age", "sex"])
        per_row = (prop.column_spec("idx", "age").row_nbytes
                   + prop.column_spec("idx", "sex").row_nbytes)
        assert st.index_bytes_scanned == N * per_row


class TestPushdown:
    @pytest.mark.parametrize("program,extract,atol", [
        (MeanProgram(), lambda r: np.asarray(r), 1e-5),
        (VarianceProgram(), lambda r: np.asarray(r["var"]), 1e-4),
        (MomentsProgram(), lambda r: np.asarray(r["var"]), 1e-4),
        (HistogramProgram(lo=-4.0, hi=4.0, bins=16),
         lambda r: np.asarray(r), 0.5),
    ])
    def test_run_where_equals_filter_then_run(self, tables, program,
                                              extract, atol):
        prop, _, data = tables
        pred = age_sex_predicate(20, 40, 1)
        session = GridSession(prop, default_eta=8)
        res, report = session.run_where(pred, program, ["age", "sex"])

        mask, _ = indexed_query(prop, pred, ["age", "sex"])
        sub = data[mask]
        if isinstance(program, MeanProgram):
            ref = sub.mean(0)
        elif isinstance(program, (VarianceProgram, MomentsProgram)):
            ref = sub.var(0)
        else:
            ref, _ = np.histogram(sub, bins=16, range=(-4.0, 4.0))
            ref = ref.astype(np.float32)
            # clipping differs at the extreme bins only
            np.testing.assert_allclose(extract(res)[1:-1], ref[1:-1],
                                       atol=atol)
            assert report.mapreduce.local_rows_read == int(mask.sum())
            return
        np.testing.assert_allclose(extract(res), ref, atol=atol)
        assert report.mapreduce.local_rows_read == int(mask.sum())

    @pytest.mark.parametrize("eta", [1, 3, 8, 50, 200])
    def test_eta_invariance_through_pushdown(self, tables, eta):
        """η is a pure performance knob: the pushdown result must not move."""
        prop, _, data = tables
        pred = age_sex_predicate(20, 40, 1)
        session = GridSession(prop)
        res, report = session.run_where(pred, MeanProgram(), ["age", "sex"],
                                        eta=eta)
        mask, _ = indexed_query(prop, pred, ["age", "sex"])
        np.testing.assert_allclose(np.asarray(res), data[mask].mean(0),
                                   atol=1e-4)
        assert report.eta == eta

    @pytest.mark.parametrize("name,pred", PREDICATES)
    def test_moves_only_selected_payload_bytes(self, tables, name, pred):
        prop, _, _ = tables
        session = GridSession(prop, default_eta=8)
        _, report = session.run_where(pred, MeanProgram(), ["age", "sex"])
        q = report.query
        row_nbytes = prop.column_spec("img", "data").row_nbytes
        assert q.payload_bytes_moved == q.rows_selected * row_nbytes
        if q.rows_selected < N:
            assert q.payload_bytes_moved < N * row_nbytes
        # the index scan never touches payload
        assert q.payload_bytes_traversed == 0

    def test_empty_selection_runs(self, tables):
        prop, _, _ = tables
        session = GridSession(prop, default_eta=8)
        res, report = session.run_where(
            age_sex_predicate(200, 300, 1), MeanProgram(), ["age", "sex"])
        assert report.query.rows_selected == 0
        assert report.query.payload_bytes_moved == 0
        assert np.all(np.isfinite(np.asarray(res)))
