"""Launch-layer tests: spec resolution, shapes, HLO parsing, probe math."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.roofline import (
    collective_bytes_from_hlo,
    derive_terms,
)
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.models.config import ModelConfig
from repro.models.params import resolve_spec, sharding_rules
from repro.utils import make_mesh


class TestResolveSpec:
    MESH = {"pod": 2, "data": 16, "model": 16}

    def test_divisible_dims_shard(self):
        rules = sharding_rules()
        spec = resolve_spec((16384, 53248), ("embed", "mlp"), rules, self.MESH)
        assert spec == P("data", "model")

    def test_non_divisible_dim_replicates(self):
        rules = sharding_rules()
        # 8 kv heads cannot shard over 16-way model
        spec = resolve_spec((8, 128), ("kv_heads", None), rules, self.MESH)
        assert spec == P()

    def test_batch_one_replicates(self):
        rules = sharding_rules()
        assert resolve_spec((1,), ("batch",), rules, self.MESH) == P()
        # batch 128 takes pod then data (128 % 32 == 0)
        spec = resolve_spec((128,), ("batch",), rules, self.MESH)
        assert spec == P(("pod", "data"))

    def test_axis_never_reused(self):
        rules = {"a": ("model",), "b": ("model",)}
        spec = resolve_spec((16, 16), ("a", "b"), rules, self.MESH)
        assert spec == P("model")  # second dim must not reuse model

    def test_size_one_axis_skipped(self):
        spec = resolve_spec((64,), ("batch",), sharding_rules(),
                            {"pod": 1, "data": 8, "model": 2})
        assert spec == P("data")


class TestShapes:
    def test_all_cells_defined(self):
        assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                               "long_500k"}

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_input_specs_no_allocation(self, arch):
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = cell_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape)

    def test_long_context_skips(self):
        skips = [a for a in ARCH_IDS
                 if not cell_applicable(get_config(a), "long_500k")[0]]
        assert len(skips) == 7  # 33 runnable + 7 documented skips = 40 cells

    def test_decode_specs_have_caches(self):
        cfg = get_config("llama3p2_1b")
        specs = input_specs(cfg, "decode_32k")
        assert "caches" in specs and "token" in specs and "pos" in specs
        k = jax.tree.leaves(specs["caches"])[0]
        assert 32768 in k.shape


class TestHLOParsing:
    HLO = """
  %ar = f32[128,1024]{1,0} all-reduce(f32[128,1024] %x), replica_groups={}
  %ag.1 = bf16[8,256]{1,0} all-gather(bf16[1,256] %y), dimensions={0}
  %rs = f32[16]{0} reduce-scatter(f32[256] %z), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4] %w), source_target_pairs={{0,1}}
  %a2a = bf16[32,32]{1,0} all-to-all(bf16[32,32] %v), dimensions={0}
  %dot = f32[128,128]{1,0} dot(f32[128,64] %a, f32[64,128] %b)
"""

    def test_collective_bytes(self):
        out = collective_bytes_from_hlo(self.HLO)
        assert out["count"] == 5
        by = out["by_op"]
        assert by["all-reduce"] == 128 * 1024 * 4 * 2.0   # ring factor 2
        assert by["all-gather"] == 8 * 256 * 2
        assert by["reduce-scatter"] == 16 * 4
        assert by["collective-permute"] == 4 * 4
        assert by["all-to-all"] == 32 * 32 * 2
        # dot must not be counted
        assert out["wire_bytes"] == sum(by.values())

    def test_start_variant_counted(self):
        hlo = "%s = f32[64]{0} all-reduce-start(f32[64] %x)"
        out = collective_bytes_from_hlo(hlo)
        assert out["count"] == 1
        assert out["wire_bytes"] == 64 * 4 * 2


class TestRooflineTerms:
    def test_dominant_selection(self):
        t = derive_terms(flops=197e12, bytes_accessed=1.0, wire_bytes=1.0)
        assert t.dominant == "compute"
        assert t.compute_s == pytest.approx(1.0)
        t = derive_terms(flops=1.0, bytes_accessed=819e9, wire_bytes=1.0)
        assert t.dominant == "memory"
        t = derive_terms(flops=1.0, bytes_accessed=1.0, wire_bytes=50e9)
        assert t.dominant == "collective"
        assert 0 < t.compute_fraction() <= 1.0


class TestProbeCorrection:
    """Probe-corrected totals must match a fully-unrolled compile."""

    def test_corrected_matches_unrolled(self):
        from repro.launch.dryrun import compile_cell
        from repro.launch.probes import corrected, make_probe_plan
        from repro.launch.shapes import ShapeSpec
        import repro.launch.shapes as shapes_mod

        cfg = ModelConfig(
            name="probecheck", family="dense", n_layers=6, d_model=64,
            n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
            remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
        )
        mesh = make_mesh((1, 1), ("data", "model"))
        # a tiny ad-hoc shape so the test is fast
        shapes_mod.SHAPES["tiny_train"] = ShapeSpec("tiny_train", 32, 4,
                                                    "train")
        try:
            scanned = compile_cell(cfg, "tiny_train", mesh, "train")
            unrolled = compile_cell(
                dataclasses.replace(cfg, scan_layers=False),
                "tiny_train", mesh, "train")
            a_cfg, bs_plan = make_probe_plan(cfg)
            a = compile_cell(a_cfg, "tiny_train", mesh, "train")
            bs = [(pb, compile_cell(pb.cfg, "tiny_train", mesh, "train"))
                  for pb in bs_plan]
            corr = corrected(a, bs)
            # scanned undercounts; corrected must match unrolled within 5%
            assert scanned["flops"] < unrolled["flops"]
            assert corr["flops"] == pytest.approx(unrolled["flops"], rel=0.05)
            assert corr["bytes"] == pytest.approx(unrolled["bytes"], rel=0.15)
        finally:
            del shapes_mod.SHAPES["tiny_train"]
