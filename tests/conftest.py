"""Shared test config: marker registration + Hypothesis profiles.

Two Hypothesis profiles keep CI fast without weakening local runs:

- ``ci``  — ``max_examples`` capped (selected automatically when the ``CI``
  env var is set, as GitHub Actions does);
- ``dev`` — the full budget (200 examples), the default everywhere else.

Select explicitly with ``HYPOTHESIS_PROFILE=ci|dev``.
"""

import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes, not ms)")


try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=20, deadline=None,
                              stateful_step_count=15)
    settings.register_profile("dev", max_examples=200, deadline=None,
                              stateful_step_count=25)
    settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:
    # A CI run that EXPLICITLY selected a hypothesis profile must not
    # silently drop the property/state-machine tests to 0 examples — that
    # is how a broken `pip install` once shipped a suite that "passed"
    # while the differential state machine never ran.  This covers both
    # the PR matrix (HYPOTHESIS_PROFILE=ci) and the nightly deep walk
    # (HYPOTHESIS_PROFILE=dev under CI).  Local containers without
    # hypothesis (no profile requested) still degrade gracefully.
    _profile = os.environ.get("HYPOTHESIS_PROFILE")
    if _profile == "ci" or (_profile and os.environ.get("CI")):
        raise RuntimeError(
            f"HYPOTHESIS_PROFILE={_profile} is set but the 'hypothesis' "
            "package is missing: the CI environment must `pip install -r "
            "requirements.txt` (which pins it). Refusing to skip the "
            "property tests silently.")
