"""Shared test config: marker registration + Hypothesis profiles.

Two Hypothesis profiles keep CI fast without weakening local runs:

- ``ci``  — ``max_examples`` capped (selected automatically when the ``CI``
  env var is set, as GitHub Actions does);
- ``dev`` — the full budget (200 examples), the default everywhere else.

Select explicitly with ``HYPOTHESIS_PROFILE=ci|dev``.
"""

import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (minutes, not ms)")


try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=20, deadline=None,
                              stateful_step_count=15)
    settings.register_profile("dev", max_examples=200, deadline=None,
                              stateful_step_count=25)
    settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:
    pass
