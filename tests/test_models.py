"""Model zoo correctness: forward shapes, NaN-freeness, and — the strong
check — decode-path equivalence: prefill(S-1) + one decode_step must
reproduce the full-sequence forward's last-token logits for EVERY family
(validates KV caches, MLA absorbed decode, SSD/RWKV recurrent states, and
the zamba2 shared-attention cache)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.config import (
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SSMConfig,
)
from repro.models.model import build_model, pad_caches


def tiny(name, **kw):
    base = dict(
        name=name, family="dense", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=128, remat_policy="none",
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": tiny("dense"),
    "qwen_bias_qknorm": tiny("qwen", qkv_bias=True, qk_norm=True, n_layers=3),
    "tied": tiny("tied", tie_embeddings=True, n_layers=2),
    "swa_moe": tiny(
        "mixtral", family="moe", sliding_window=8,
        # capacity high enough that the tiny test batch never drops —
        # drops make prefill(S-1) and full(S) legitimately diverge
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      capacity_factor=8.0),
    ),
    "mla_moe": tiny(
        "deepseek", family="moe", n_kv_heads=4,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=32,
                      n_shared_experts=1, first_k_dense=1,
                      capacity_factor=8.0),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    ),
    "rwkv": tiny(
        "rwkv", family="ssm", n_layers=3, d_ff=160,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8),
        block_pattern=("rwkv",),
    ),
    "zamba_hybrid": tiny(
        "zamba", family="hybrid", n_layers=7, n_kv_heads=4,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=8),
        block_pattern=("ssm", "ssm", "ssm", "attn_shared"),
    ),
}


@pytest.fixture(scope="module", params=list(CONFIGS))
def setup(request):
    cfg = CONFIGS[request.param]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    return cfg, model, params, tokens


class TestForward:
    def test_shapes_and_no_nans(self, setup):
        cfg, model, params, tokens = setup
        logits, aux = jax.jit(model.forward_train)(params, tokens)
        assert logits.shape == (*tokens.shape, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
        assert not bool(jnp.isnan(aux))

    def test_causality(self, setup):
        """Changing the flat-last token must not change any other logit.

        (Capacity-based MoE has cross-ROW competition — an earlier row's
        routing can evict a later row's token, the standard GShard/Switch
        artifact — so the only strictly-safe perturbation is the token that
        is last in flat [B*S] order.)"""
        cfg, model, params, tokens = setup
        logits1, _ = model.forward_train(params, tokens)
        perturbed = tokens.at[-1, -1].set((tokens[-1, -1] + 1) % cfg.vocab)
        logits2, _ = model.forward_train(params, perturbed)
        l1 = np.asarray(logits1).reshape(-1, cfg.vocab)[:-1]
        l2 = np.asarray(logits2).reshape(-1, cfg.vocab)[:-1]
        np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)

    def test_causality_single_row(self, setup):
        """Within one row, future tokens never affect past logits."""
        cfg, model, params, tokens = setup
        row = tokens[:1]
        logits1, _ = model.forward_train(params, row)
        perturbed = row.at[0, -1].set((row[0, -1] + 1) % cfg.vocab)
        logits2, _ = model.forward_train(params, perturbed)
        np.testing.assert_allclose(
            np.asarray(logits1[:, :-1]), np.asarray(logits2[:, :-1]),
            rtol=2e-4, atol=2e-4,
        )

    def test_grads_flow_and_finite(self, setup):
        cfg, model, params, tokens = setup

        def loss(p):
            logits, aux = model.forward_train(p, tokens)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            tgt = jnp.roll(tokens, -1, axis=1)
            nll = -jnp.take_along_axis(lp, tgt[..., None], -1).mean()
            return nll + 0.01 * aux

        g = jax.grad(loss)(params)
        leaves = jax.tree.leaves(g)
        assert all(bool(jnp.isfinite(x).all()) for x in leaves)
        # at least the embedding must receive gradient
        assert float(jnp.abs(g["embed"]["table"]).sum()) > 0


class TestDecodeEquivalence:
    def test_prefill_plus_decode_matches_full(self, setup):
        cfg, model, params, tokens = setup
        B, S = tokens.shape
        full_logits, _ = model.forward_train(params, tokens)
        want = np.asarray(full_logits[:, -1])

        logits_p, caches = model.prefill(params, tokens[:, : S - 1])
        caches = pad_caches(cfg, caches, S)
        got, _ = model.decode_step(
            params, tokens[:, S - 1],
            jnp.full((B,), S - 1, jnp.int32), caches)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)

    def test_two_step_decode(self, setup):
        """decode twice; step-2 must match full forward at position S-1."""
        cfg, model, params, tokens = setup
        B, S = tokens.shape
        full_logits, _ = model.forward_train(params, tokens)

        logits_p, caches = model.prefill(params, tokens[:, : S - 2])
        caches = pad_caches(cfg, caches, S)
        g1, caches = model.decode_step(
            params, tokens[:, S - 2], jnp.full((B,), S - 2, jnp.int32), caches)
        np.testing.assert_allclose(
            np.asarray(g1), np.asarray(full_logits[:, -2]), rtol=2e-3, atol=2e-3)
        g2, _ = model.decode_step(
            params, tokens[:, S - 1], jnp.full((B,), S - 1, jnp.int32), caches)
        np.testing.assert_allclose(
            np.asarray(g2), np.asarray(full_logits[:, -1]), rtol=2e-3, atol=2e-3)


class TestEncDec:
    @pytest.fixture(scope="class")
    def whisper(self):
        cfg = ModelConfig(
            name="wh", family="audio", n_layers=3, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=128, vocab=128, remat_policy="none",
            dtype=jnp.float32, param_dtype=jnp.float32,
            encoder=EncoderConfig(n_layers=2, n_frames=24, d_model=64,
                                  n_heads=4, d_ff=128),
        )
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        frames = jax.random.normal(jax.random.key(2), (2, 24, 64))
        tokens = jax.random.randint(jax.random.key(1), (2, 8), 0, 128)
        return cfg, model, params, frames, tokens

    def test_forward(self, whisper):
        cfg, model, params, frames, tokens = whisper
        logits, _ = jax.jit(model.forward_train)(params, frames, tokens)
        assert logits.shape == (2, 8, 128)
        assert not bool(jnp.isnan(logits).any())

    def test_decode_equivalence(self, whisper):
        cfg, model, params, frames, tokens = whisper
        B, S = tokens.shape
        full_logits, _ = model.forward_train(params, frames, tokens)
        _, (caches, kv) = model.prefill(params, frames, tokens[:, : S - 1])
        from repro.models.model import _pad_attn_cache
        caches = _pad_attn_cache(cfg, caches, S)
        got, _ = model.decode_step(
            params, tokens[:, S - 1], jnp.full((B,), S - 1, jnp.int32),
            (caches, kv))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(full_logits[:, -1]),
            rtol=2e-3, atol=2e-3)


class TestVLMStub:
    def test_mrope_embeds_path(self):
        cfg = tiny("vlm", family="vlm", mrope=True, n_layers=2)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        B, S = 2, 12
        embeds = jax.random.normal(jax.random.key(3), (B, S, cfg.d_model))
        pos3 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None],
                                (B, 3, S))
        logits, _ = model.forward_train(params, embeds=embeds, positions=pos3)
        assert logits.shape == (B, S, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
