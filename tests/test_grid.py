"""GridSession: the five-verb facade, mutation epochs, plan cache,
incremental placement.  (The >1-device incrementality path is covered in
test_multidevice.py; here the mesh is whatever the main process has.)"""

import numpy as np
import pytest

import jax

from repro.core.balancer import NodeSpec, assign_new_regions
from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table


def make_population(n=64, payload=(3, 4), seed=0, split_bytes=10**18):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=payload,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=split_bytes),
    )
    t.upload(
        [f"img{i:05d}" for i in range(n)],
        {"img": {"data": rng.normal(size=(n,) + payload).astype(np.float32)},
         "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                 "age": rng.uniform(4, 80, n).astype(np.float32),
                 "sex": rng.integers(0, 2, n).astype(np.int8)}},
    )
    return t


def row_batch(keys, seed=1, payload=(3, 4)):
    rng = np.random.default_rng(seed)
    n = len(keys)
    return {"img": {"data": rng.normal(size=(n,) + payload).astype(np.float32)},
            "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                    "age": rng.uniform(4, 80, n).astype(np.float32),
                    "sex": rng.integers(0, 2, n).astype(np.int8)}}


class TestVerbs:
    def test_upload_retrieve_remove_roundtrip(self):
        s = GridSession(make_population(32), default_eta=8)
        assert s.epoch == 0
        n = s.upload(["zz1", "zz2"], row_batch(["zz1", "zz2"]))
        assert n == 2 and s.epoch == 1
        keys, vals = s.retrieve("img", "data", rowkey="zz1")
        assert keys[0] == b"zz1"
        assert s.remove(rowkey="zz1") == 1
        assert s.epoch == 2
        assert len(s.retrieve("img", "data", rowkey="zz1")[0]) == 0

    def test_run_matches_numpy_across_mutations(self):
        t = make_population(48)
        s = GridSession(t, default_eta=8)
        res, rep = s.run(MeanProgram())
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data").mean(0), atol=1e-5)
        assert rep.epoch == 0 and not rep.plan_cache_hit

        s.upload(["new1"], row_batch(["new1"]))
        s.remove(rowkey="img00000")
        res2, rep2 = s.run(MeanProgram())
        np.testing.assert_allclose(
            np.asarray(res2), t.column("img", "data").mean(0), atol=1e-5)
        assert rep2.epoch == 2

    def test_noop_mutations_do_not_advance_epoch(self):
        s = GridSession(make_population(16), default_eta=8)
        # duplicate skipped -> nothing written -> same epoch
        assert s.upload(["img00003"], row_batch(["img00003"])) == 0
        assert s.remove(rowkey="nope") == 0
        assert s.epoch == 0

    def test_rebalance_moves_toward_proportional(self):
        t = make_population(96, split_bytes=40_000_000)  # many regions
        nodes = [NodeSpec(0, cores=1, mips=1.0)]
        D = jax.device_count()
        if D == 1:
            # single device: rebalance must be a no-op
            s = GridSession(t, nodes=nodes)
            assert s.rebalance() == []
            return
        s = GridSession(t, nodes=[NodeSpec(i, cores=1, mips=i + 1)
                                  for i in range(D)])
        moved = s.rebalance(tolerance=0.05)
        assert isinstance(moved, list)
        assert s.imbalance() < 1.0

    def test_rebalance_rejects_new_node_ids(self):
        s = GridSession(make_population(16))
        with pytest.raises(ValueError):
            s.rebalance(nodes=[NodeSpec(99)])


class TestPlanCache:
    def test_repeat_run_hits_cache_and_does_not_recompile(self):
        s = GridSession(make_population(48), default_eta=8)
        _, r1 = s.run(MeanProgram())
        compiles = s.engine.compile_count
        assert compiles >= 1 and not r1.plan_cache_hit
        _, r2 = s.run(MeanProgram())
        assert r2.plan_cache_hit
        assert s.engine.compile_count == compiles  # acceptance criterion
        assert s.metrics.plan_hits == 1

    def test_mutation_invalidates_plan_but_reuses_executable(self):
        t = make_population(48)
        s = GridSession(t, default_eta=8)
        s.run(MeanProgram())
        compiles = s.engine.compile_count
        # overwrite keeps row count (and layout shape) unchanged
        s.upload(["img00001"], row_batch(["img00001"], seed=7),
                 on_duplicate="overwrite")
        res, rep = s.run(MeanProgram())
        assert not rep.plan_cache_hit          # new epoch, new plan
        assert s.engine.compile_count == compiles  # same shapes, no recompile
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data").mean(0), atol=1e-5)

    def test_distinct_programs_get_distinct_plans(self):
        s = GridSession(make_population(32), default_eta=8)
        s.run(MeanProgram())
        _, r = s.run(VarianceProgram())
        assert not r.plan_cache_hit
        assert s.metrics.plan_misses == 2


class TestIncrementalFolds:
    def test_repeat_stats_folds_zero_rows(self):
        # the fold-engine acceptance criterion: a repeat query at an
        # unchanged table reads zero payload rows
        s = GridSession(make_population(48), default_eta=8)
        _, r1 = s.run(MeanProgram())
        assert r1.query.rows_folded == 48
        _, r2 = s.run(MeanProgram())
        q = r2.query
        assert q.rows_folded == 0
        assert q.partials_total > 0
        assert q.partials_reused == q.partials_total
        assert r2.mapreduce.local_rows_read == 0

    def test_overwrite_refolds_only_dirty_region(self):
        s = GridSession(make_population(64, split_bytes=40_000_000),
                        default_eta=8)
        assert len(s.table.regions) > 1
        s.run(MeanProgram())
        s.upload(["img00002"], row_batch(["img00002"], seed=3),
                 on_duplicate="overwrite")
        _, r = s.run(MeanProgram())
        q = r.query
        assert q.partials_reused == q.partials_total - 1
        dirty = s.table.regions.region_for(b"img00002")
        assert q.rows_folded == dirty.num_rows(s.table.keys)

    def test_partials_are_eta_keyed(self):
        s = GridSession(make_population(16), default_eta=4)
        s.run(MeanProgram())
        _, r2 = s.run(MeanProgram(), eta=8)   # new chunking → re-fold
        assert not r2.plan_cache_hit and r2.query.rows_folded == 16
        _, r3 = s.run(MeanProgram(), eta=8)   # now cached at η=8 too
        assert r3.plan_cache_hit and r3.query.rows_folded == 0

    def test_dirty_regions_counted(self):
        s = GridSession(make_population(32))
        s.upload(["aa"], row_batch(["aa"]))
        assert s.metrics.regions_dirtied >= 1

    def test_skipped_duplicates_do_not_dirty_their_regions(self):
        s = GridSession(make_population(64, split_bytes=40_000_000))
        assert len(s.table.regions) > 1
        # batch of existing keys (skipped) + ONE new key: only the new
        # key's region may be invalidated
        batch = [f"img{i:05d}" for i in range(32)] + ["zzz"]
        assert s.upload(batch, row_batch(batch)) == 1
        assert s.metrics.regions_dirtied == 1

    def test_stale_results_evicted(self):
        s = GridSession(make_population(64, split_bytes=40_000_000),
                        default_eta=8)
        q = s.scan(prefix="img0000").map(MeanProgram())
        q.collect()
        assert len(s._results) == 1
        # mutations far from the scanned regions never unbind the entry —
        # only idling past the TTL evicts it
        for i in range(GridSession.RESULT_TTL_EPOCHS + 2):
            k = f"zz{i:03d}"
            s.upload([k], row_batch([k], seed=i))
        assert len(s._results) == 0
        res, _ = s.scan(prefix="img0000").map(MeanProgram()).collect()
        np.testing.assert_allclose(
            np.asarray(res),
            s.table.column("img", "data")[:10].mean(0), atol=1e-5)


class TestAdoption:
    def test_assign_new_regions_prefers_neediest_node(self):
        nodes = [NodeSpec(0, mips=1.0), NodeSpec(1, mips=1.0)]
        current = {0: 0}  # node 0 already holds 100 bytes
        out = assign_new_regions(current, {0: 100, 1: 10}, nodes)
        assert out == {1: 1}  # node 1 has the larger deficit

    def test_assign_new_regions_noop_when_complete(self):
        nodes = [NodeSpec(0), NodeSpec(1)]
        assert assign_new_regions({0: 0, 1: 1}, {0: 5, 1: 5}, nodes) == {}


class TestTokenDataset:
    def test_session_dataset_shares_placement(self):
        from repro.data.pipeline import synthetic_token_table
        table = synthetic_token_table(n_rows=64, seq_len=17, vocab=97)
        s = GridSession(table, payload_family="tok",
                        payload_qualifier="ids")
        ds = s.token_dataset(global_batch=jax.device_count() * 2)
        assert ds.placement is s.placement
        batch = ds.next_batch(0)
        assert batch.shape == (jax.device_count() * 2, 17)
