"""Multi-device integration: runs the colocation path on 8 fake CPU devices.

The main pytest process must keep the single real device (smoke tests and
benches depend on it), so these run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_snippet(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_colocated_mapreduce_8dev():
    out = run_snippet("""
        import numpy as np, jax
        from repro.core.table import make_mip_table, ColumnSpec
        from repro.core.balancer import NodeSpec
        from repro.core.placement import Placement
        from repro.core.mapreduce import MapReduceEngine
        from repro.core.stats import MeanProgram, VarianceProgram
        from repro.core.query import indexed_query, age_sex_predicate, mask_to_device_layout
        from repro.core.regions import HierarchicalSplitPolicy
        from repro.utils import make_mesh

        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        n = 300
        t = make_mip_table(
            payload_shape=(8, 8),
            extra_index_columns=[ColumnSpec('age', (), np.float32),
                                 ColumnSpec('sex', (), np.int8)],
            split_policy=HierarchicalSplitPolicy(max_region_bytes=12 * 10_000_000))
        data = rng.normal(size=(n, 8, 8)).astype(np.float32)
        ages = rng.uniform(4, 80, n).astype(np.float32)
        sexes = rng.integers(0, 2, n).astype(np.int8)
        t.upload([f'img{i:05d}' for i in range(n)],
                 {'img': {'data': data},
                  'idx': {'size': rng.integers(6_000_000, 20_000_001, n),
                          'age': ages, 'sex': sexes}})

        mesh = make_mesh((8,), ('data',))
        nodes = [NodeSpec(i, cores=1, mips=1.0 + 0.2 * (i % 3)) for i in range(8)]
        pl = Placement.from_strategy(t, nodes, 'greedy')
        vals, valid = pl.put_column(mesh, 'img', 'data', chunk_size=16)

        # colocation: each device shard holds exactly its placement's rows
        counts = pl.node_row_counts()
        per_dev = np.asarray(valid).sum(axis=1)
        for d in range(8):
            assert per_dev[d] == counts[d], (d, per_dev[d], counts[d])

        eng = MapReduceEngine(mesh)
        res, st = eng.run(MeanProgram(), vals, valid, chunk_size=16)
        assert np.allclose(np.asarray(res), data.mean(0), atol=1e-5)
        assert st.local_rows_read == n

        resv, _ = eng.run(VarianceProgram(), vals, valid, chunk_size=16)
        assert np.allclose(np.asarray(resv['var']), data.var(0), atol=1e-4)

        mask, qs = indexed_query(t, age_sex_predicate(20, 40, 1), ['age', 'sex'])
        row_ids, vl = pl.device_layout(chunk_size=16)
        dm = mask_to_device_layout(mask, row_ids, vl)
        sub, _ = eng.run(MeanProgram(), vals, valid, chunk_size=16,
                         row_mask=jax.device_put(dm, pl.data_sharding(mesh)))
        assert np.allclose(np.asarray(sub), data[mask].mean(0), atol=1e-5)
        assert qs.payload_bytes_traversed == 0
        print('MULTIDEVICE_OK')
    """)
    assert "MULTIDEVICE_OK" in out


@pytest.mark.slow
def test_grid_session_incremental_8dev():
    """A mutation into ONE region re-gathers, re-ships, and RE-FOLDS only
    that region's block on its owner device; every other device's block and
    fold partial is reused, and the repeated program never recompiles at a
    fixed block shape."""
    out = run_snippet("""
        import numpy as np, jax
        from repro.core.grid import GridSession
        from repro.core.regions import HierarchicalSplitPolicy
        from repro.core.stats import MeanProgram
        from repro.core.table import make_mip_table, ColumnSpec

        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        n = 256
        t = make_mip_table(
            payload_shape=(6, 6),
            extra_index_columns=[ColumnSpec('age', (), np.float32),
                                 ColumnSpec('sex', (), np.int8)],
            split_policy=HierarchicalSplitPolicy(
                max_region_bytes=16 * 13_000_000))
        def batch(nk, seed):
            r = np.random.default_rng(seed)
            return {'img': {'data': r.normal(size=(nk, 6, 6)).astype(np.float32)},
                    'idx': {'size': r.integers(6_000_000, 20_000_001, nk),
                            'age': r.uniform(4, 80, nk).astype(np.float32),
                            'sex': r.integers(0, 2, nk).astype(np.int8)}}
        t.upload([f'img{i:05d}' for i in range(n)], batch(n, 0))

        s = GridSession(t, default_eta=8)
        res, rep1 = s.run(MeanProgram())
        assert np.allclose(np.asarray(res), t.column('img', 'data').mean(0),
                           atol=1e-5)
        q1 = rep1.query
        assert q1.rows_folded == n and q1.partials_reused == 0, q1
        compiles = s.engine.compile_count

        # overwrite one existing row: exactly one region (one node) dirty
        s.upload(['img00000'], batch(1, 9), on_duplicate='overwrite')
        res2, rep2 = s.run(MeanProgram())
        assert np.allclose(np.asarray(res2),
                           t.column('img', 'data').mean(0), atol=1e-5)
        q2 = rep2.query
        assert q2.partials_reused == q2.partials_total - 1, q2
        assert q2.blocks_transferred == 1 and q2.gather_count == 1, q2
        dirty = t.regions.region_for(b'img00000')
        assert q2.rows_folded == dirty.num_rows(t.keys), q2
        assert s.engine.compile_count == compiles      # no recompile
        assert not rep2.plan_cache_hit                 # but a fresh result

        # rebalance: partials are placement-independent, nothing re-folds
        moved = s.rebalance(tolerance=0.01)
        res3, rep3 = s.run(MeanProgram())
        assert np.allclose(np.asarray(res3),
                           t.column('img', 'data').mean(0), atol=1e-5)
        assert rep3.query.rows_folded == 0, rep3.query
        print('GRID_INCREMENTAL_OK', len(moved))
    """)
    assert "GRID_INCREMENTAL_OK" in out


@pytest.mark.slow
def test_tree_reduce_merge_8dev():
    """The merge phase tree-reduces across owner devices: each device
    pre-merges its own partials locally, one psum over the data axis joins
    them, and finalize runs replicated — no single-device funnel.  Grouped
    and ungrouped additive programs take it; non-additive merges and a
    forced ``merge_strategy="funnel"`` fall back, with identical results."""
    out = run_snippet("""
        import numpy as np, jax
        from repro.core.grid import GridSession
        from repro.core.stats import (CountProgram, MeanProgram,
                                      VarianceProgram)
        from repro.core.table import make_mip_table, ColumnSpec

        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        groups = [f'g{i:02d}' for i in range(32)]       # high region count
        t = make_mip_table(
            payload_shape=(4, 4),
            extra_index_columns=[ColumnSpec('site', (), np.int32)],
            presplit_keys=groups[1:])
        keys = [f'{g}x{i:03d}' for g in groups for i in range(6)]
        n = len(keys)
        data = rng.normal(size=(n, 4, 4)).astype(np.float32)
        t.upload(keys, {'img': {'data': data},
                        'idx': {'size': rng.integers(6_000_000, 20_000_001, n),
                                'site': rng.integers(0, 4, n).astype(np.int32)}})
        s = GridSession(t, default_eta=4)

        # additive ungrouped: tree
        res, rep = s.run(MeanProgram())
        assert rep.query.merge_path == 'tree', rep.query
        assert np.allclose(np.asarray(res), data.mean(0), atol=1e-5)

        # grouped additive: tree, values match the groupby oracle
        gr, grep = (s.scan().group_by('idx:site').map(MeanProgram())
                    .map(VarianceProgram()).map(CountProgram())
                    .reduce().collect())
        assert grep.query.merge_path == 'tree', grep.query
        sites = t.column('idx', 'site'); d2 = t.column('img', 'data')
        m, v, c = gr.values
        for g, k in enumerate(gr.keys):
            sel = d2[sites == k]
            assert np.allclose(np.asarray(m)[g], sel.mean(0), atol=1e-4)
            assert np.allclose(np.asarray(v['var'])[g], sel.var(0), atol=1e-3)
            assert int(np.asarray(c)[g]) == len(sel)

        # forced funnel agrees bit-for-bit-ish with the tree reduce
        s2 = GridSession(t, default_eta=4)
        s2.engine.merge_strategy = 'funnel'
        res_f, rep_f = s2.run(MeanProgram())
        assert rep_f.query.merge_path == 'funnel'
        assert np.allclose(np.asarray(res_f), np.asarray(res), atol=1e-6)

        # non-additive (Chan variance standalone) falls back to funnel
        _, rep_v = s.run(VarianceProgram())
        assert rep_v.query.merge_path == 'funnel', rep_v.query

        # rebalance re-homes cached partials into the tree merge
        s.rebalance(tolerance=0.0)
        res3, rep3 = s.run(MeanProgram())
        assert rep3.query.rows_folded == 0, rep3.query
        assert np.allclose(np.asarray(res3), data.mean(0), atol=1e-5)
        print('TREE_REDUCE_OK', s.engine.merge_path_counts)
    """)
    assert "TREE_REDUCE_OK" in out


@pytest.mark.slow
def test_sketch_merge_order_invariance_8dev():
    """Acceptance: sketch results are BIT-identical whichever merge path
    runs — the 8-device tree reduce (psum for count leaves, pmax for the
    HLL registers) vs the forced single-stream funnel.  Int32 sums and
    maxes carry no rounding, so this is exact equality, not allclose."""
    out = run_snippet("""
        import numpy as np, jax
        from repro.core.grid import GridSession
        from repro.core.stats import (CountMinProgram, HyperLogLogProgram,
                                      QuantileSketchProgram)
        from repro.core.table import make_mip_table, ColumnSpec

        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        groups = [f'g{i:02d}' for i in range(32)]       # high region count
        t = make_mip_table(
            payload_shape=(4, 4),
            extra_index_columns=[ColumnSpec('site', (), np.int32)],
            presplit_keys=groups[1:])
        keys = [f'{g}x{i:03d}' for g in groups for i in range(6)]
        n = len(keys)
        data = rng.normal(size=(n, 4, 4)).astype(np.float32)
        t.upload(keys, {'img': {'data': data},
                        'idx': {'size': rng.integers(6_000_000, 20_000_001, n),
                                'site': rng.integers(0, 4, n).astype(np.int32)}})

        def plan(sess):
            return (sess.scan().select('img:data')
                    .map(CountMinProgram(depth=4, width=1024, seed=51))
                    .map(HyperLogLogProgram(p=10, seed=52))
                    .map(QuantileSketchProgram(
                        lo=-5.0, hi=5.0, log2_universe=11, depth=4,
                        width=1024, probes=(0.5, 0.9), seed=53))
                    .reduce())

        s = GridSession(t, default_eta=4)
        res_t, rep_t = plan(s).collect()
        assert rep_t.query.merge_path == 'tree', rep_t.query

        s2 = GridSession(t, default_eta=4)
        s2.engine.merge_strategy = 'funnel'
        res_f, rep_f = plan(s2).collect()
        assert rep_f.query.merge_path == 'funnel', rep_f.query

        lt, lf = jax.tree.leaves(res_t), jax.tree.leaves(res_f)
        assert len(lt) == len(lf)
        for a, b in zip(lt, lf):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                'tree vs funnel sketch state diverged'

        # and chunking is irrelevant too: different eta, same bits
        res_e, _ = plan(GridSession(t, default_eta=4)).collect(eta=16)
        for a, b in zip(lt, jax.tree.leaves(res_e)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print('SKETCH_MERGE_OK')
    """)
    assert "SKETCH_MERGE_OK" in out


@pytest.mark.slow
def test_grouped_sketch_rebalance_4dev():
    """Grouped sketch query on 4 devices: per-group estimates match the
    exact oracles, and a rebalance re-homes the cached group-keyed sketch
    partials without re-folding a row or changing a bit of the answer."""
    out = run_snippet("""
        import numpy as np, jax
        from repro.core import ref
        from repro.core.grid import GridSession
        from repro.core.stats import HyperLogLogProgram, QuantileSketchProgram
        from repro.core.table import make_mip_table, ColumnSpec

        assert jax.device_count() == 4
        rng = np.random.default_rng(1)
        groups = [f'r{i:02d}' for i in range(16)]
        t = make_mip_table(
            payload_shape=(4, 4),
            extra_index_columns=[ColumnSpec('site', (), np.int32)],
            presplit_keys=groups[1:])
        keys = [f'{g}x{i:03d}' for g in groups for i in range(8)]
        n = len(keys)
        data = rng.normal(size=(n, 4, 4)).astype(np.float32)
        t.upload(keys, {'img': {'data': data},
                        'idx': {'size': rng.integers(6_000_000, 20_000_001, n),
                                'site': rng.integers(0, 3, n).astype(np.int32)}})

        hll = HyperLogLogProgram(p=10, seed=61)
        qs = QuantileSketchProgram(lo=-5.0, hi=5.0, log2_universe=11,
                                   depth=4, width=1024, probes=(0.5,),
                                   seed=62)
        def plan(sess):
            return (sess.scan().select('img:data').group_by('idx:site')
                    .map(hll).map(qs).reduce())

        s = GridSession(t, default_eta=4)
        res1, rep1 = plan(s).collect()
        sites = t.column('idx', 'site')
        hll_res, q_res = res1.values
        for g, k in enumerate(res1.keys):
            sub = data[sites == k]
            true_d = ref.exact_distinct(sub)
            est = float(np.asarray(hll_res['estimate'])[g])
            assert abs(est - true_d) <= 4 * hll.std_error() * true_d
            n_g = sub.size
            v = np.asarray(q_res['quantiles'])[g]
            below, _ = ref.rank_interval(sub, v - qs.value_resolution())
            _, at_or_below = ref.rank_interval(sub,
                                               v + qs.value_resolution())
            err = ref.interval_distance(np.ceil(0.5 * n_g),
                                        below, at_or_below)
            assert (err <= qs.rank_error_bound(n_g) + 1).all()

        moved = s.rebalance(tolerance=0.0)
        res2, rep2 = plan(s).collect()
        assert rep2.query.rows_folded == 0, rep2.query
        assert list(res1.keys) == list(res2.keys)
        for a, b in zip(jax.tree.leaves(res1.values),
                        jax.tree.leaves(res2.values)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                'rebalance changed grouped sketch bits'
        print('GROUPED_SKETCH_OK', len(moved))
    """, devices=4)
    assert "GROUPED_SKETCH_OK" in out


@pytest.mark.slow
def test_int8_pod_compressed_train_step_8dev():
    """2 pods × 2 data × 2 model: the int8-DCN gradient sync must train
    equivalently (within quantization error) to the plain step."""
    out = run_snippet("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.config import ModelConfig
        from repro.models.model import build_model
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.train.step import (TrainStepConfig, make_train_step,
                                      make_compressed_train_step)
        from repro.utils import make_mesh

        assert jax.device_count() == 8
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                          n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                          remat_policy="none",
                          dtype=jnp.float32, param_dtype=jnp.float32)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        opt = adamw_init(params)
        tokens = jax.random.randint(jax.random.key(1), (8, 17), 0, 64)

        plain = jax.jit(make_train_step(cfg, model, AdamWConfig(lr=1e-3)))
        comp = jax.jit(make_compressed_train_step(
            cfg, model, AdamWConfig(lr=1e-3), mesh))

        p1, o1, m1 = plain(params, opt, tokens, 0)
        with mesh:
            p2, o2, m2 = comp(params, opt, tokens, jnp.zeros((), jnp.int32))
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        # pod-local losses get pmean'd; must agree with the global loss
        assert abs(l1 - l2) < 5e-2, (l1, l2)
        # parameter updates agree within int8 quantization error
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, worst
        print("COMPRESSED_OK", l1, l2, worst)
    """)
    assert "COMPRESSED_OK" in out
