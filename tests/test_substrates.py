"""Substrate tests: optimizer, schedules, compression, checkpointing, data
pipeline, and a small end-to-end training integration (loss must drop)."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager, latest_step
from repro.data.pipeline import (
    ColocatedTokenDataset,
    synthetic_image_population,
    synthetic_token_table,
)
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compression import int8_compress, int8_decompress
from repro.optim.schedule import linear_warmup_cosine
from repro.train.step import TrainStepConfig, make_train_state, make_train_step
from repro.utils import make_mesh


class TestAdamW:
    def test_quadratic_converges(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip_norm=None)
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_weight_decay_mask(self):
        cfg = AdamWConfig(lr=0.0, weight_decay=1.0, grad_clip_norm=None)
        params = {"w": jnp.ones(3), "norm_scale": jnp.ones(3)}
        state = adamw_init(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw_update(cfg, params, zero_g, state)
        # lr=0: nothing moves regardless — use lr>0 to see decay selectivity
        cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip_norm=None)
        new, _, _ = adamw_update(cfg, params, zero_g, adamw_init(params))
        assert float(new["w"][0]) < 1.0            # decayed
        assert float(new["norm_scale"][0]) == 1.0  # masked (name contains norm)

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        g = {"w": jnp.full(4, 100.0)}
        _, _, gnorm = adamw_update(cfg, params, g, adamw_init(params))
        assert float(gnorm) == pytest.approx(200.0)  # pre-clip norm reported

    def test_schedule(self):
        s0 = linear_warmup_cosine(jnp.asarray(0), 10, 100)
        s10 = linear_warmup_cosine(jnp.asarray(10), 10, 100)
        s100 = linear_warmup_cosine(jnp.asarray(100), 10, 100)
        assert float(s0) == 0.0
        assert float(s10) == pytest.approx(1.0, abs=0.02)
        assert float(s100) == pytest.approx(0.1, abs=0.02)


class TestCompression:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        tree = {"a": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
                "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32) * 10)}
        q, s = int8_compress(tree)
        out = int8_decompress(q, s)
        for k in tree:
            err = np.abs(np.asarray(out[k]) - np.asarray(tree[k])).max()
            scale = float(np.abs(np.asarray(tree[k])).max())
            assert err <= scale / 127 + 1e-6  # one quantization bucket

    def test_int8_dtype_on_wire(self):
        q, _ = int8_compress({"a": jnp.ones((8,), jnp.float32)})
        assert q["a"].dtype == jnp.int8


class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        d = str(tmp_path / "ckpt")
        mgr = CheckpointManager(d, keep_last=2)
        tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
                "opt": {"m": jnp.zeros((2, 3))}}
        for step in (1, 2, 3, 4):
            mgr.save(step, tree, metadata={"next_step": step}, async_=False)
        assert mgr.latest_step() == 4
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                       if n.startswith("step_"))
        assert steps == [3, 4]  # retention

        template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        restored, meta = mgr.restore(template)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"]))
        assert meta["next_step"] == 4

    def test_async_save(self, tmp_path):
        d = str(tmp_path / "ckpt")
        mgr = CheckpointManager(d)
        mgr.save(7, {"w": jnp.ones(3)}, async_=True)
        mgr.wait()
        assert latest_step(d) == 7

    def test_shape_mismatch_raises(self, tmp_path):
        d = str(tmp_path / "ckpt")
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.ones(3)}, async_=False)
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.ones(4)})

    def test_crash_safe_tmp_never_restored(self, tmp_path):
        d = str(tmp_path / "ckpt")
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": jnp.ones(3)}, async_=False)
        os.makedirs(os.path.join(d, "step_000000009.tmp"))
        assert latest_step(d) == 1  # tmp dirs are invisible


class TestDataPipeline:
    def test_colocated_batches(self):
        table = synthetic_token_table(n_rows=64, seq_len=32, vocab=100)
        mesh = make_mesh((jax.device_count(),), ("data",))
        ds = ColocatedTokenDataset(table, mesh, global_batch=8)
        b0 = ds.next_batch(0)
        b0_again = ds.next_batch(0)
        b1 = ds.next_batch(1)
        assert b0.shape == (8, 32)
        np.testing.assert_array_equal(np.asarray(b0), np.asarray(b0_again))
        assert not np.array_equal(np.asarray(b0), np.asarray(b1))
        assert int(jnp.max(b0)) < 100

    def test_population_strata(self):
        t = synthetic_image_population(payload_shape=(4, 4, 4), scale=0.05)
        ages = t.column("idx", "age")
        sexes = t.column("idx", "sex")
        assert t.num_rows > 200
        # all four strata populated for both sexes
        for lo, hi in ((4, 20), (20, 40), (40, 60), (60, 98)):
            sel = (ages >= lo) & (ages < hi)
            assert (sexes[sel] == 0).sum() > 0
            assert (sexes[sel] == 1).sum() > 0


class TestTrainIntegration:
    def test_loss_decreases_tiny_lm(self, tmp_path):
        cfg = ModelConfig(
            name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_ff=128, vocab=128, remat_policy="none",
            dtype=jnp.float32, param_dtype=jnp.float32,
        )
        model = build_model(cfg)
        params, opt_state = make_train_state(cfg, model, jax.random.key(0))
        step = jax.jit(make_train_step(
            cfg, model, AdamWConfig(lr=1e-3),
            TrainStepConfig(num_microbatches=2)))
        table = synthetic_token_table(n_rows=128, seq_len=33, vocab=128)
        mesh = make_mesh((jax.device_count(),), ("data",))
        ds = ColocatedTokenDataset(table, mesh, global_batch=8)

        losses = []
        for i in range(30):
            batch = ds.next_batch(i)
            params, opt_state, metrics = step(params, opt_state, batch, i)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]
        assert np.isfinite(losses).all()

    def test_resume_from_checkpoint(self, tmp_path):
        from repro.train.trainer import Trainer, TrainerConfig
        cfg = ModelConfig(
            name="tiny", family="dense", n_layers=1, d_model=32, n_heads=2,
            n_kv_heads=1, d_ff=64, vocab=64, remat_policy="none",
            dtype=jnp.float32, param_dtype=jnp.float32,
        )
        model = build_model(cfg)
        params, opt_state = make_train_state(cfg, model, jax.random.key(0))
        step = jax.jit(make_train_step(cfg, model, AdamWConfig(lr=1e-3)))
        table = synthetic_token_table(n_rows=32, seq_len=17, vocab=64)
        mesh = make_mesh((jax.device_count(),), ("data",))
        ds = ColocatedTokenDataset(table, mesh, global_batch=4)

        tc = TrainerConfig(total_steps=6, log_every=100, checkpoint_every=3,
                           checkpoint_dir=str(tmp_path / "ck"))
        trainer = Trainer(step, ds, tc)
        p1, o1, _ = trainer.run(params, opt_state)

        # resume: a fresh trainer must pick up at step 6 (no-op run)
        trainer2 = Trainer(step, ds, tc)
        p2, o2, hist = trainer2.run(params, opt_state)
        np.testing.assert_allclose(
            np.asarray(p1["embed"]["table"]),
            np.asarray(p2["embed"]["table"]), rtol=1e-6)
