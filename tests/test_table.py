"""Unit tests for the TensorTable columnar store (HBase analogue)."""

import numpy as np
import pytest

from repro.core.regions import (
    ConstantSizeSplitPolicy,
    HierarchicalSplitPolicy,
    RegionSet,
)
from repro.core.table import (
    ColumnFamily,
    ColumnSpec,
    TensorTable,
    make_mip_table,
    make_naive_table,
)


def small_table(split_bytes=10**18):
    return make_mip_table(
        payload_shape=(4,),
        extra_index_columns=[ColumnSpec("age", (), np.float32)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=split_bytes),
    )


def upload_rows(t, keys, seed=0, sizes=None, ages=None):
    rng = np.random.default_rng(seed)
    n = len(keys)
    payload = rng.normal(size=(n, 4)).astype(np.float32)
    sizes = np.full(n, 10, dtype=np.int64) if sizes is None else np.asarray(sizes)
    ages = rng.uniform(0, 90, n).astype(np.float32) if ages is None else ages
    t.upload(keys, {"img": {"data": payload}, "idx": {"size": sizes, "age": ages}})
    return payload


class TestUploadRetrieve:
    def test_roundtrip_sorted(self):
        t = small_table()
        payload = upload_rows(t, ["b", "a", "c"])
        keys, vals = t.retrieve("img", "data")
        assert [k.decode() for k in keys] == ["a", "b", "c"]
        # values must follow the sorted key order
        np.testing.assert_array_equal(vals[0], payload[1])
        np.testing.assert_array_equal(vals[1], payload[0])
        t.check_invariants()

    def test_single_rowkey_and_range(self):
        t = small_table()
        upload_rows(t, [f"k{i:03d}" for i in range(20)])
        keys, vals = t.retrieve("img", "data", rowkey="k007")
        assert len(keys) == 1 and keys[0] == b"k007"
        keys, _ = t.retrieve("img", "data", start="k005", stop="k010")
        assert [k.decode() for k in keys] == [f"k{i:03d}" for i in range(5, 10)]

    def test_skip_list(self):
        t = small_table()
        upload_rows(t, [f"k{i}" for i in range(5)])
        keys, _ = t.retrieve("img", "data", skip=["k1", "k3"])
        assert [k.decode() for k in keys] == ["k0", "k2", "k4"]

    def test_duplicate_skipped_without_overwrite(self):
        t = small_table()
        upload_rows(t, ["a", "b"], seed=0)
        before = t.retrieve("img", "data", rowkey="a")[1].copy()
        n = t.upload(
            ["a"],
            {
                "img": {"data": np.ones((1, 4), np.float32)},
                "idx": {"size": np.array([10]), "age": np.array([1.0], np.float32)},
            },
            overwrite=False,
        )
        assert n == 0
        np.testing.assert_array_equal(t.retrieve("img", "data", rowkey="a")[1], before)

    def test_overwrite_updates(self):
        t = small_table()
        upload_rows(t, ["a", "b"])
        n = t.upload(
            ["a"],
            {
                "img": {"data": np.ones((1, 4), np.float32)},
                "idx": {"size": np.array([10]), "age": np.array([1.0], np.float32)},
            },
            overwrite=True,
        )
        assert n == 1
        np.testing.assert_array_equal(
            t.retrieve("img", "data", rowkey="a")[1][0], np.ones(4, np.float32)
        )
        assert t.num_rows == 2

    def test_on_duplicate_error_raises_and_writes_nothing(self):
        t = small_table()
        upload_rows(t, ["a", "c"], seed=0)
        before = t.retrieve("img", "data")[1].copy()
        with pytest.raises(KeyError):
            t.upload(
                ["b", "a"],  # mixes an insert with a cross-batch duplicate
                {
                    "img": {"data": np.ones((2, 4), np.float32)},
                    "idx": {"size": np.full(2, 10, np.int64),
                            "age": np.ones(2, np.float32)},
                },
                on_duplicate="error",
            )
        assert t.num_rows == 2
        np.testing.assert_array_equal(t.retrieve("img", "data")[1], before)

    def test_cross_batch_duplicates_independent_of_batch_order(self):
        """The documented contract: per-row handling never depends on where
        the duplicate sits in the (possibly unsorted) batch."""
        for batch in (["d", "c", "b", "a"], ["a", "b", "c", "d"],
                      ["b", "d", "a", "c"]):
            t = small_table()
            upload_rows(t, ["b", "d"], seed=0)
            kept = {k: t.retrieve("img", "data", rowkey=k)[1][0].copy()
                    for k in ("b", "d")}
            n = t.upload(
                batch,
                {
                    "img": {"data": np.ones((4, 4), np.float32)},
                    "idx": {"size": np.full(4, 10, np.int64),
                            "age": np.ones(4, np.float32)},
                },
                on_duplicate="skip",
            )
            assert n == 2  # only the two inserts
            assert [k.decode() for k in t.keys] == ["a", "b", "c", "d"]
            for k in ("b", "d"):  # duplicates kept their first-uploaded value
                np.testing.assert_array_equal(
                    t.retrieve("img", "data", rowkey=k)[1][0], kept[k])
            for k in ("a", "c"):  # inserts took the batch's value
                np.testing.assert_array_equal(
                    t.retrieve("img", "data", rowkey=k)[1][0],
                    np.ones(4, np.float32))
            t.check_invariants()

    def test_on_duplicate_overwrite_takes_latest(self):
        t = small_table()
        upload_rows(t, ["b", "d"], seed=0)
        t.upload(
            ["d", "a"],
            {
                "img": {"data": np.full((2, 4), 9.0, np.float32)},
                "idx": {"size": np.full(2, 10, np.int64),
                        "age": np.ones(2, np.float32)},
            },
            on_duplicate="overwrite",
        )
        np.testing.assert_array_equal(
            t.retrieve("img", "data", rowkey="d")[1][0],
            np.full(4, 9.0, np.float32))
        t.check_invariants()

    def test_unknown_on_duplicate_mode(self):
        t = small_table()
        upload_rows(t, ["a"])
        with pytest.raises(ValueError):
            t.upload(["a"], {"img": {"data": np.ones((1, 4), np.float32)},
                             "idx": {"size": np.array([10]),
                                     "age": np.ones(1, np.float32)}},
                     on_duplicate="bogus")

    def test_schema_validation(self):
        t = small_table()
        with pytest.raises(ValueError):
            t.upload(["a"], {"img": {"data": np.ones((1, 5), np.float32)},
                             "idx": {"size": np.array([1]),
                                     "age": np.array([1.0], np.float32)}})
        with pytest.raises(ValueError):
            t.upload(["a"], {"img": {"data": np.ones((1, 4), np.float32)}})

    def test_delete(self):
        t = small_table()
        upload_rows(t, [f"k{i}" for i in range(10)])
        removed = t.delete(start="k2", stop="k5")
        assert removed == 3
        assert t.num_rows == 7
        t.check_invariants()


class TestRegions:
    def test_split_on_threshold(self):
        t = small_table(split_bytes=50)
        upload_rows(t, [f"k{i:02d}" for i in range(16)],
                    sizes=np.full(16, 10, np.int64))
        # 160 logical bytes, 50-byte threshold -> >= 4 regions
        assert len(t.regions) >= 4
        t.check_invariants()

    def test_hierarchical_split_balances_bytes(self):
        t = small_table(split_bytes=1000)
        # one huge row then many small: hierarchical split puts the huge row
        # alone-ish; byte imbalance between children stays bounded
        sizes = np.array([900] + [20] * 20, dtype=np.int64)
        upload_rows(t, [f"k{i:02d}" for i in range(21)], sizes=sizes)
        rb = list(t.region_bytes().values())
        assert len(rb) >= 2
        assert max(rb) <= 1000  # no region exceeds a sane multiple of threshold

    def test_presplit(self):
        t = make_mip_table(
            payload_shape=(4,),
            extra_index_columns=[ColumnSpec("age", (), np.float32)],
            presplit_keys=["k05", "k10"],
        )
        assert len(t.regions) == 3
        upload_rows(t, [f"k{i:02d}" for i in range(15)])
        counts = list(t.region_row_counts().values())
        assert sorted(counts) == [5, 5, 5]

    def test_region_set_invariants_after_many_splits(self):
        rs = RegionSet(ConstantSizeSplitPolicy(max_region_bytes=25))
        keys = np.array([f"r{i:04d}".encode() for i in range(64)], dtype="S64")
        sizes = np.full(64, 10, np.int64)
        rs.maybe_split(keys, sizes)
        rs.check_invariants()
        total = sum(r.num_rows(keys) for r in rs)
        assert total == 64


class TestByteAccounting:
    def test_logical_vs_physical(self):
        t = small_table()
        upload_rows(t, ["a", "b"], sizes=np.array([7_000_000, 19_000_000]))
        assert t.total_bytes() == 26_000_000
        naive = make_naive_table(payload_shape=(4,))
        n = 3
        naive.upload(
            [f"k{i}" for i in range(n)],
            {"img": {"data": np.zeros((n, 4), np.float32),
                     "size": np.full(n, 5, np.int64)}},
        )
        assert naive.total_bytes() == 15
