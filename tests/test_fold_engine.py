"""Block-granular fold engine: per-block partial caching, fused-program CSE,
the adaptive compact gather, BlockStore-routed retrieves, and the Pallas
map phase.

The PR acceptance oracles live here and in test_grid/test_differential:
a repeat ``.stats()`` on an unchanged epoch folds zero payload rows; a
single-region mutation re-folds only that region's blocks; a CSE'd fused
mean+variance+moments computes each shared accumulator once per chunk
(FLOP-counted against the naive fusion) while matching independently-run
member programs within float tolerance.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.grid import GridSession
from repro.core.mapreduce import MapReduceEngine
from repro.core.query import age_sex_predicate
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    CountProgram,
    FusedProgram,
    HistogramProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table
from repro.utils import make_mesh

PAYLOAD = (3, 4)


def make_table(groups=("a", "b", "c", "d", "e"), per=8, seed=0):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=list(groups)[1:],
    )
    keys = [f"{g}{i:04d}" for g in groups for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "sex": rng.integers(0, 2, n).astype(np.int8)}})
    return t


# ----------------------------------------------------------------------
# engine units: per-block folds merge to the layout-at-a-time answer
# ----------------------------------------------------------------------

class TestBlockFoldEngine:
    @pytest.mark.parametrize("program,eta", [
        (MeanProgram(), 4),
        (VarianceProgram(), 3),
        (MomentsProgram(), 7),
        (HistogramProgram(lo=-4.0, hi=4.0, bins=16), 5),
    ])
    def test_blockwise_equals_monolithic(self, program, eta):
        rng = np.random.default_rng(1)
        mesh = make_mesh((jax.device_count(),), ("data",))
        eng = MapReduceEngine(mesh)
        blocks = [rng.normal(size=(r,) + PAYLOAD).astype(np.float32)
                  for r in (5, 9, 1, 12)]
        partials = [eng.fold_block(program, jnp.asarray(b), None, eta,
                                   PAYLOAD, np.float32) for b in blocks]
        got = eng.merge_finalize(program, partials, PAYLOAD, np.float32)

        data = np.concatenate(blocks)
        cap = -(-len(data) // eta) * eta
        vals = np.zeros((1, cap) + PAYLOAD, np.float32)
        vals[0, :len(data)] = data
        valid = np.zeros((1, cap), bool)
        valid[0, :len(data)] = True
        # single-shard reference fold (mesh-independent ground truth)
        ref, _ = MapReduceEngine(make_mesh((1,), ("data",))).run(
            program, vals, valid, eta)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-4),
            got, ref)

    def test_masked_fold_skips_rows(self):
        rng = np.random.default_rng(2)
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        block = rng.normal(size=(10,) + PAYLOAD).astype(np.float32)
        mask = np.zeros(10, bool)
        mask[[1, 4, 7]] = True
        p = eng.fold_block(MeanProgram(), jnp.asarray(block),
                           jnp.asarray(mask), 4, PAYLOAD, np.float32)
        got = eng.merge_finalize(MeanProgram(), [p], PAYLOAD, np.float32)
        np.testing.assert_allclose(np.asarray(got), block[mask].mean(0),
                                   atol=1e-5)

    def test_zero_partials_finalize_identity(self):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        got = eng.merge_finalize(MeanProgram(), [], PAYLOAD, np.float32)
        assert np.all(np.asarray(got) == 0)  # sum 0 / max(count,1)

    def test_fold_cost_reports_flops(self):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        cost = eng.fold_cost(MeanProgram(), 16, PAYLOAD, jnp.float32, 4)
        assert cost["flops"] >= 0 and cost["bytes"] >= 0


# ----------------------------------------------------------------------
# partial cache: content-addressed sharing across plans and epochs
# ----------------------------------------------------------------------

class TestPartialCache:
    def test_range_covering_whole_regions_shares_full_partials(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())                       # full partials for a..e
        r = s.scan(start="a", stop="c").map(MeanProgram()).stats()
        q = r.query
        # regions a and b are fully covered: mask sig "full" matches the
        # full-table partials — nothing re-folds, no blocks touched
        assert q.partials_total == 2
        assert q.partials_reused == 2 and q.rows_folded == 0, q

    def test_same_selection_different_predicate_objects_share(self):
        t = make_table(per=16, seed=3)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.0)
        p1 = age_sex_predicate(20, 40, 1)
        p2 = age_sex_predicate(20, 40, 1)          # distinct object, same rows
        r1 = (s.scan(prefix="b").where(p1, ["age", "sex"])
              .map(MeanProgram()).stats())
        r2 = (s.scan(prefix="b").where(p2, ["age", "sex"])
              .map(MeanProgram()).stats())
        # mask signatures are content hashes, not object identities
        assert r2.plan_cache_hit
        assert r2.query.rows_folded == 0
        assert r1.query.rows_selected == r2.query.rows_selected

    def test_partials_survive_block_cache_eviction(self):
        t = make_table()                            # 5 regions
        s = GridSession(t, default_eta=4, block_cache_cap=2)
        s.run(MeanProgram())
        assert s.blocks.evictions >= 3
        _, r = s.run(MeanProgram())
        # evicted BLOCKS don't matter: the partials carry the repeat
        assert r.plan_cache_hit and r.query.rows_folded == 0

    def test_partial_cache_eviction_refolds_losslessly(self):
        t = make_table()
        s = GridSession(t, default_eta=4, partial_cache_cap=2)
        res, _ = s.run(MeanProgram())
        _, r2 = s.run(MeanProgram())                # result cache still hits
        assert r2.plan_cache_hit
        s._results.clear()                          # force the partial path
        res3, r3 = s.run(MeanProgram())
        assert r3.query.rows_folded > 0             # some partials re-folded
        np.testing.assert_allclose(np.asarray(res3), np.asarray(res),
                                   atol=1e-5)

    def test_distinct_programs_keep_distinct_partials(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        _, r = s.run(VarianceProgram())
        q = r.query
        assert q.partials_reused == 0 and q.rows_folded > 0
        # but the BLOCKS are shared: no re-gather, no re-transfer
        assert q.gather_count == 0
        assert q.blocks_reused == q.blocks_total


# ----------------------------------------------------------------------
# adaptive compact gather (cold low-selectivity one-shots)
# ----------------------------------------------------------------------

class TestCompactGather:
    def pred_few(self):
        # selects exactly the rows with sex == 1 and age in a sliver
        return age_sex_predicate(None, 6.0, None)

    def test_cold_selective_scan_goes_compact(self):
        t = make_table(per=32, seed=5)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.2)
        pred = self.pred_few()
        mask = pred({"age": t.column("idx", "age"),
                     "sex": t.column("idx", "sex")})
        if not mask.any():
            pytest.skip("seed selected nothing")
        res, rep = s.run_where(pred, MeanProgram(), ["age", "sex"])
        q = rep.query
        assert q.gather_path == "compact", q
        assert q.partials_total == 0 and q.blocks_total == 0, q
        assert q.rows_folded == int(mask.sum()), q
        # only the selected rows crossed to the device
        row_nbytes = t.column_spec("img", "data").row_nbytes
        assert q.payload_bytes_transferred == int(mask.sum()) * row_nbytes
        np.testing.assert_allclose(
            np.asarray(res), t.column("img", "data")[mask].mean(0),
            atol=1e-5)
        # one-shot: nothing entered the block or partial caches
        assert len(s.blocks) == 0 and s.blocks.partial_count == 0
        assert s.metrics.compact_scans == 1
        # ...but the finalized result is memoized: an identical repeat
        # (fresh plan object) pays neither gather nor fold
        res2, rep2 = s.run_where(pred, MeanProgram(), ["age", "sex"])
        assert rep2.plan_cache_hit
        assert rep2.query.gather_path == "compact"
        assert rep2.query.rows_folded == 0
        rep2.query.check_partial_invariant()
        np.testing.assert_array_equal(np.asarray(res2), np.asarray(res))
        assert s.metrics.compact_scans == 1         # no second gather pass

    def test_has_partials_index_tracks_versions(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        rid = t.regions.region_for(b"a0000").rid
        assert s.blocks.has_partials(rid)
        s.remove(rowkey=b"a0000")                   # version bump: stale now
        assert not s.blocks.has_partials(rid)
        s.run(MeanProgram())                        # re-folds current version
        assert s.blocks.has_partials(rid)
        s.blocks.clear_partials()
        assert not s.blocks.has_partials(rid)

    def test_resident_blocks_override_compact(self):
        t = make_table(per=32, seed=5)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.2)
        s.run(MeanProgram())                        # blocks now resident
        res, rep = s.run_where(self.pred_few(), MeanProgram(),
                               ["age", "sex"])
        assert rep.query.gather_path == "blocks"    # reuse beats cold cost
        assert rep.query.gather_count == 0          # ...and pays off

    def test_threshold_zero_disables_compact(self):
        t = make_table(per=32, seed=5)
        s = GridSession(t, default_eta=4, compact_gather_threshold=0.0)
        _, rep = s.run_where(self.pred_few(), MeanProgram(), ["age", "sex"])
        assert rep.query.gather_path == "blocks"

    def test_threshold_exposed_on_session(self):
        s = GridSession(make_table(), compact_gather_threshold=0.25)
        assert s.compact_gather_threshold == 0.25


# ----------------------------------------------------------------------
# retrieves route through the BlockStore
# ----------------------------------------------------------------------

class TestRetrieveThroughBlocks:
    def test_second_retrieve_rereads_nothing(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        (k1, c1), r1 = s.scan(prefix="b").select("img:data").collect()
        assert r1.query.gather_path == "retrieve"
        assert r1.query.gather_count == 1           # cold: one region read
        (k2, c2), r2 = s.scan(prefix="b").select("img:data").collect()
        assert r2.query.gather_count == 0           # host block reused
        assert r2.query.blocks_reused == r2.query.blocks_total == 1
        np.testing.assert_array_equal(c1["img:data"], c2["img:data"])
        np.testing.assert_array_equal(c1["img:data"],
                                      t.column("img", "data")[8:16])

    def test_fold_after_retrieve_shares_the_gather(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        s.scan(prefix="b").select("img:data").collect()
        _, rep = s.scan(prefix="b").map(MeanProgram()).collect()
        # the fold commits the retrieve's host block to its device —
        # zero table re-reads
        assert rep.query.gather_count == 0, rep.query

    def test_multi_column_retrieve(self):
        t = make_table()
        s = GridSession(t, default_eta=4)
        (keys, cols), rep = (s.scan(prefix="c")
                             .select("img:data", "idx:age").collect())
        np.testing.assert_array_equal(cols["img:data"],
                                      t.column("img", "data")[16:24])
        np.testing.assert_array_equal(cols["idx:age"],
                                      t.column("idx", "age")[16:24])
        rep.query.check_block_invariant()


# ----------------------------------------------------------------------
# fused-program CSE: equality property + FLOP accounting
# ----------------------------------------------------------------------

CSE_MEMBERS = (MeanProgram(), VarianceProgram(), MomentsProgram())


class TestFusedCSE:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_cse_matches_independent_runs(self, seed):
        """Property: the CSE'd fusion equals each member run standalone
        (up to float associativity), across random tables/etas."""
        rng = np.random.default_rng(seed)
        t = make_table(per=int(rng.integers(3, 12)), seed=seed)
        eta = int(rng.integers(1, 9))
        s = GridSession(t, default_eta=eta)
        q = s.scan()
        for p in CSE_MEMBERS + (HistogramProgram(lo=-4, hi=4, bins=8),
                                CountProgram()):
            q = q.map(p)
        fused_res, _ = q.collect()
        for p, got in zip(CSE_MEMBERS + (HistogramProgram(lo=-4, hi=4,
                                                          bins=8),
                                         CountProgram()), fused_res):
            solo = GridSession(t, default_eta=eta)
            want, _ = solo.run(p)
            jax.tree.map(
                lambda a, b: np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-3),
                got, want)

    def test_cse_and_naive_fusion_agree(self):
        t = make_table(per=10, seed=7)
        data = t.column("img", "data")
        s = GridSession(t, default_eta=4)
        (m1, v1, mo1), _ = (s.scan().map(MeanProgram())
                            .map(VarianceProgram()).map(MomentsProgram())
                            .collect())
        np.testing.assert_allclose(np.asarray(m1), data.mean(0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(v1["var"]), data.var(0),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(mo1["var"]), data.var(0),
                                   atol=1e-4)

    def test_cse_fold_costs_fewer_flops_than_naive(self):
        """The accumulators really are computed once: XLA's own CSE cannot
        recover the naive fusion's duplicated folds."""
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        cse = FusedProgram(CSE_MEMBERS)
        naive = FusedProgram(CSE_MEMBERS, cse=False)
        fc = eng.fold_cost(cse, 64, PAYLOAD, jnp.float32, 8)
        fn = eng.fold_cost(naive, 64, PAYLOAD, jnp.float32, 8)
        if fc["flops"] == 0 or fn["flops"] == 0:
            pytest.skip("cost_analysis reports no flops on this backend")
        assert fc["flops"] < 0.9 * fn["flops"], (fc, fn)

    def test_cse_partial_is_single_accumulator_set(self):
        cse = FusedProgram(CSE_MEMBERS)
        zero = cse.zero(PAYLOAD, np.float32)
        # one float32 pool with count + s1..s4, and no private partials
        assert zero["private"] == ()
        (dt, pool), = ((k, v) for k, v in zero["shared"].items())
        assert set(pool) == {"count", "s1", "s2", "s3", "s4"}
        assert cse.additive

    def test_non_cse_members_keep_private_folds(self):
        fused = FusedProgram((MeanProgram(), CountProgram(),
                              HistogramProgram()))
        zero = fused.zero(PAYLOAD, np.float32)
        assert len(zero["private"]) == 2       # count (int32) + histogram
        res = fused.finalize(fused.map_chunk(
            jnp.ones((4,) + PAYLOAD), jnp.ones((4,), bool)))
        assert int(res[1]) == 4                # exact int32 count survives


# ----------------------------------------------------------------------
# Pallas map phase (opt-in impl="pallas")
# ----------------------------------------------------------------------

class TestPallasMapPhase:
    def test_mean_ref_vs_pallas_equivalence(self):
        t = make_table(per=10, seed=2)
        s = GridSession(t, default_eta=4)
        ref, _ = s.run(MeanProgram(), impl="ref")
        pal, rep = s.run(MeanProgram(), impl="pallas")
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   atol=1e-5)
        assert rep.query.partials_total == len(t.regions)

    def test_variance_ref_vs_pallas_equivalence(self):
        t = make_table(per=10, seed=2)
        s = GridSession(t, default_eta=4)
        ref, _ = s.run(VarianceProgram())
        pal, _ = s.run(VarianceProgram(), impl="pallas")
        np.testing.assert_allclose(np.asarray(pal["mean"]),
                                   np.asarray(ref["mean"]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(pal["var"]),
                                   np.asarray(ref["var"]), atol=1e-4)
        np.testing.assert_allclose(float(pal["count"]), float(ref["count"]))

    def test_pallas_partials_cache_separately_from_ref(self):
        t = make_table(per=10, seed=2)
        s = GridSession(t, default_eta=4)
        s.run(MeanProgram())
        _, rep = s.run(MeanProgram(), impl="pallas")
        assert rep.query.partials_reused == 0      # kernel identity differs
        _, rep2 = s.run(MeanProgram(), impl="pallas")
        assert rep2.query.rows_folded == 0         # but caches like any other

    def test_unsupported_program_raises(self):
        from repro.kernels.streaming_stats.ops import kernel_map_program
        with pytest.raises(ValueError):
            kernel_map_program(HistogramProgram())
        with pytest.raises(ValueError):
            kernel_map_program(MeanProgram(), impl="cuda")

    def test_grouped_fold_ref_vs_pallas_equivalence(self):
        """The fused fold kernel (session-level ``fold_impl="pallas"``)
        extends the ref-vs-pallas equivalence to GROUPED folds — the
        map-phase ``impl="pallas"`` twin never covered those."""
        def grouped(s):
            return (s.scan().select("img:data").group_by("idx:sex")
                    .map(MeanProgram()).map(VarianceProgram())
                    .map(MomentsProgram()).reduce().collect())
        ref, _ = grouped(GridSession(make_table(per=10, seed=2),
                                     default_eta=4, fold_impl="xla"))
        s = GridSession(make_table(per=10, seed=2), default_eta=4,
                        fold_impl="pallas", fold_interpret=True)
        pal, _ = grouped(s)
        assert s.engine.fold_path_counts["pallas"] > 0
        assert list(pal.keys) == list(ref.keys)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-4, atol=1e-3),
            list(pal.values), list(ref.values))
