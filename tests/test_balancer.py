"""Unit tests for the allocation strategies and the offline rebalancer."""

import numpy as np
import pytest

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    balanced_allocation,
    central_allocation,
    greedy_allocation,
    node_loads,
    powers_from_observations,
    rebalance,
)


def hetero_nodes():
    # the paper's shape: slow 12-core and fast 32-core machines
    slow = [NodeSpec(i, cores=12, mips=1.0) for i in range(8)]
    fast = [NodeSpec(8 + i, cores=32, mips=1.6) for i in range(4)]
    return slow + fast


def many_regions(n=240, seed=0):
    rng = np.random.default_rng(seed)
    return {i: int(b) for i, b in enumerate(rng.integers(6e6, 20e6, n))}


class TestGreedy:
    def test_proportional_to_power(self):
        nodes = hetero_nodes()
        rb = many_regions()
        alloc = greedy_allocation(rb, nodes)
        loads = node_loads(alloc, rb, nodes)
        total_b = sum(rb.values())
        total_p = sum(n.power for n in nodes)
        for n in nodes:
            target = total_b * n.power / total_p
            # within one max-region of the proportional target
            assert abs(loads[n.node_id] - target) <= max(rb.values())

    def test_beats_balanced_on_hetero(self):
        nodes = hetero_nodes()
        rb = many_regions()
        g = allocation_imbalance(greedy_allocation(rb, nodes), rb, nodes)
        b = allocation_imbalance(balanced_allocation(rb, nodes), rb, nodes)
        assert g < b
        assert g < 0.05

    def test_homogeneous_equals_balanced_quality(self):
        nodes = [NodeSpec(i, cores=4, mips=1.0) for i in range(8)]
        rb = {i: 10**7 for i in range(64)}  # uniform regions
        g = allocation_imbalance(greedy_allocation(rb, nodes), rb, nodes)
        b = allocation_imbalance(balanced_allocation(rb, nodes), rb, nodes)
        assert g == pytest.approx(0.0, abs=1e-9)
        assert b == pytest.approx(0.0, abs=1e-9)

    def test_all_regions_assigned_to_live_nodes(self):
        nodes = hetero_nodes()
        rb = many_regions(17)
        for fn in (greedy_allocation, balanced_allocation, central_allocation):
            alloc = fn(rb, nodes)
            assert set(alloc) == set(rb)
            assert set(alloc.values()) <= {n.node_id for n in nodes}


class TestRebalance:
    def test_fixes_balanced_start(self):
        nodes = hetero_nodes()
        rb = many_regions()
        start = balanced_allocation(rb, nodes)
        imb0 = allocation_imbalance(start, rb, nodes)
        out, moved = rebalance(start, rb, nodes, tolerance=0.05)
        imb1 = allocation_imbalance(out, rb, nodes)
        assert imb1 < imb0
        assert imb1 < 0.10
        assert 0 < len(moved) < len(rb)  # moved some, not everything

    def test_noop_when_already_proportional(self):
        nodes = hetero_nodes()
        rb = many_regions()
        good = greedy_allocation(rb, nodes)
        out, moved = rebalance(good, rb, nodes, tolerance=0.20)
        assert len(moved) <= len(rb) // 20  # near-zero churn from a good start

    def test_orphan_adoption_on_failure(self):
        nodes = hetero_nodes()
        rb = many_regions()
        alloc = greedy_allocation(rb, nodes)
        survivors = [n for n in nodes if n.node_id not in (0, 9)]
        out, moved = rebalance(alloc, rb, survivors)
        live = {n.node_id for n in survivors}
        assert set(out.values()) <= live
        orphans = [r for r, nid in alloc.items() if nid in (0, 9)]
        assert set(orphans) <= set(moved)
        assert allocation_imbalance(out, rb, survivors) < 0.15


class TestObservedPowers:
    def test_straggler_deweighted(self):
        nodes = [NodeSpec(0, cores=1, mips=1.0), NodeSpec(1, cores=1, mips=1.0)]
        # node 1 keeps taking 4x longer per round
        obs = {0: [1.0, 1.0, 1.0], 1: [4.0, 4.0, 4.0]}
        updated = powers_from_observations(obs, nodes)
        assert updated[0].power > 3 * updated[1].power
