"""MapReduce engine + stats programs (single-device mesh; the 8-device path
is covered by test_multidevice.py in a subprocess)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.balancer import NodeSpec
from repro.core.mapreduce import MapReduceEngine
from repro.core.placement import Placement
from repro.core.query import (
    age_sex_predicate,
    indexed_query,
    mask_to_device_layout,
    naive_query,
)
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    HistogramProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table, make_naive_table
from repro.utils import make_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((jax.device_count(),), ("data",))


@pytest.fixture(scope="module")
def population():
    rng = np.random.default_rng(42)
    n = 257  # deliberately not a chunk multiple
    data = rng.normal(size=(n, 6, 5)).astype(np.float32)
    ages = rng.uniform(4, 80, n).astype(np.float32)
    sexes = rng.integers(0, 2, n).astype(np.int8)
    sizes = rng.integers(6_000_000, 20_000_001, n)
    t = make_mip_table(
        payload_shape=(6, 5),
        extra_index_columns=[
            ColumnSpec("age", (), np.float32),
            ColumnSpec("sex", (), np.int8),
        ],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=300_000_000),
    )
    t.upload(
        [f"img{i:05d}" for i in range(n)],
        {"img": {"data": data},
         "idx": {"size": sizes, "age": ages, "sex": sexes}},
    )
    return t, data, ages, sexes


def layout(mesh, table, chunk=16, strategy="greedy"):
    D = mesh.shape["data"]
    nodes = [NodeSpec(i, cores=1, mips=1.0) for i in range(D)]
    pl = Placement.from_strategy(table, nodes, strategy)
    vals, valid = pl.put_column(mesh, "img", "data", chunk_size=chunk)
    return pl, vals, valid


class TestPrograms:
    def test_mean_matches_numpy(self, mesh, population):
        t, data, *_ = population
        _, vals, valid = layout(mesh, t)
        res, stats = MapReduceEngine(mesh).run(MeanProgram(), vals, valid, 16)
        np.testing.assert_allclose(np.asarray(res), data.mean(0), atol=1e-5)
        assert stats.local_rows_read == len(data)

    def test_variance_matches_numpy(self, mesh, population):
        t, data, *_ = population
        _, vals, valid = layout(mesh, t)
        res, _ = MapReduceEngine(mesh).run(VarianceProgram(), vals, valid, 16)
        np.testing.assert_allclose(np.asarray(res["var"]), data.var(0), atol=1e-4)
        np.testing.assert_allclose(np.asarray(res["mean"]), data.mean(0), atol=1e-5)
        assert int(res["count"]) == len(data)

    def test_moments_match_scipy_formulas(self, mesh, population):
        t, data, *_ = population
        _, vals, valid = layout(mesh, t)
        res, _ = MapReduceEngine(mesh).run(MomentsProgram(), vals, valid, 16)
        m = data.mean(0)
        np.testing.assert_allclose(np.asarray(res["mean"]), m, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res["var"]), data.var(0), atol=1e-4)
        sk = ((data - m) ** 3).mean(0) / data.std(0) ** 3
        np.testing.assert_allclose(np.asarray(res["skew"]), sk, atol=1e-3)

    def test_histogram_matches_numpy(self, mesh, population):
        t, data, *_ = population
        _, vals, valid = layout(mesh, t)
        prog = HistogramProgram(lo=-4.0, hi=4.0, bins=32)
        res, _ = MapReduceEngine(mesh).run(prog, vals, valid, 16)
        ref, _ = np.histogram(data, bins=32, range=(-4.0, 4.0))
        # clipping differs at the extreme edges only
        assert abs(float(np.asarray(res).sum()) - data.size) < 1e-3
        np.testing.assert_allclose(np.asarray(res)[1:-1], ref[1:-1], atol=1)


class TestChunkInvariance:
    @pytest.mark.parametrize("eta", [1, 7, 16, 64, 300])
    def test_mean_invariant_to_eta(self, mesh, population, eta):
        t, data, *_ = population
        _, vals, valid = layout(mesh, t, chunk=eta)
        res, stats = MapReduceEngine(mesh).run(MeanProgram(), vals, valid, eta)
        np.testing.assert_allclose(np.asarray(res), data.mean(0), atol=1e-4)
        assert stats.chunk_size == eta

    def test_rounds_decrease_with_eta(self, mesh, population):
        t, *_ = population
        _, vals, valid = layout(mesh, t, chunk=1)
        eng = MapReduceEngine(mesh)
        _, s1 = eng.run(MeanProgram(), vals, valid, 1)
        _, s8 = eng.run(MeanProgram(), vals, valid, 8)
        assert s8.rounds < s1.rounds
        assert s8.chunks < s1.chunks


class TestQueryIntegration:
    def test_indexed_and_naive_same_mask(self, population):
        t, data, ages, sexes = population
        naive = make_naive_table(
            payload_shape=(6, 5),
            extra_index_columns=[
                ColumnSpec("age", (), np.float32),
                ColumnSpec("sex", (), np.int8),
            ],
        )
        naive.upload(
            [f"img{i:05d}" for i in range(len(data))],
            {"img": {"data": data, "size": t.column("idx", "size"),
                     "age": ages, "sex": sexes}},
        )
        pred = age_sex_predicate(20, 40, sex=1)
        m1, s1 = indexed_query(t, pred, ["age", "sex"])
        m2, s2 = naive_query(naive, pred, ["age", "sex"])
        np.testing.assert_array_equal(m1, m2)
        # the whole point of the scheme: indexed touches no payload bytes
        assert s1.payload_bytes_traversed == 0
        assert s2.payload_bytes_traversed > 1000 * s1.index_bytes_scanned

    def test_subset_average(self, mesh, population):
        t, data, ages, sexes = population
        pl, vals, valid = layout(mesh, t)
        mask, _ = indexed_query(t, age_sex_predicate(20, 40, 1), ["age", "sex"])
        row_ids, vl = pl.device_layout(chunk_size=16)
        dm = mask_to_device_layout(mask, row_ids, vl)
        res, stats = MapReduceEngine(mesh).run(
            MeanProgram(), vals, valid, 16,
            row_mask=jax.device_put(dm, pl.data_sharding(mesh)),
        )
        ref = data[mask].mean(0)
        np.testing.assert_allclose(np.asarray(res), ref, atol=1e-5)
        assert stats.local_rows_read == int(mask.sum())


class TestPlacementLayout:
    def test_all_rows_covered_exactly_once(self, mesh, population):
        t, *_ = population
        pl, _, _ = layout(mesh, t)
        row_ids, valid = pl.device_layout(chunk_size=16)
        seen = row_ids[valid]
        assert len(seen) == t.num_rows
        assert len(np.unique(seen)) == t.num_rows

    def test_capacity_too_small_raises(self, mesh, population):
        t, *_ = population
        D = mesh.shape["data"]
        nodes = [NodeSpec(i) for i in range(D)]
        pl = Placement.from_strategy(t, nodes, "greedy")
        with pytest.raises(ValueError):
            pl.device_layout(capacity=1)
