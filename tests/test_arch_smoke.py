"""Per-architecture smoke tests: reduced config of the same family runs one
forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.step import make_train_state, make_train_step
from repro.train.loss import encdec_loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params, opt_state = make_train_state(cfg, model, jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)

    if cfg.is_encdec:
        frames = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder.n_frames, cfg.d_model),
            cfg.dtype)
        logits, aux = model.forward_train(params, frames, tokens)
        assert logits.shape == (B, S, cfg.vocab)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

        def loss_fn(p, toks):
            return encdec_loss(cfg, model, p, frames, toks)
        step = jax.jit(make_train_step(cfg, model, AdamWConfig(lr=1e-3),
                                       loss_fn=loss_fn))
    else:
        logits, aux = model.forward_train(params, tokens)
        assert logits.shape == (B, S, cfg.vocab)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        assert not bool(jnp.isnan(aux))
        step = jax.jit(make_train_step(cfg, model, AdamWConfig(lr=1e-3)))

    p2, o2, metrics = step(params, opt_state, tokens, 0)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = float(jnp.abs(
        p2["embed"]["table"] - params["embed"]["table"]).max())
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_structure(arch):
    """Full configs build runs/shapes consistently (no allocation)."""
    cfg = get_config(arch, reduced=False)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    assert n_params > 1e8, f"{arch}: suspiciously small ({n_params})"
    # axes tree must mirror the params tree exactly (resolver contract)
    axes = model.logical_axes()
    jax.tree.map(
        lambda s, a: None, shapes, axes,
        is_leaf=lambda x: x is None or (
            isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)),
    )


EXPECTED_PARAMS = {
    # ±12% of the nameplate count (our stacks omit minor vendor details)
    "llama3_405b": 405e9,
    "llama3p2_1b": 1.24e9,
    "qwen2p5_14b": 14.8e9,
    "qwen3_8b": 8.2e9,
    "mixtral_8x7b": 46.7e9,
    "deepseek_v3_671b": 671e9,
    "whisper_large_v3": 1.54e9,
    "rwkv6_3b": 3.1e9,
    "qwen2_vl_7b": 7.6e9,   # LM backbone only (vision tower is the stub)
    "zamba2_1p2b": 1.2e9,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_near_nameplate(arch):
    cfg = get_config(arch, reduced=False)
    n = cfg.param_count()
    want = EXPECTED_PARAMS[arch]
    assert 0.80 * want < n < 1.25 * want, (
        f"{arch}: {n/1e9:.2f}B vs nameplate {want/1e9:.2f}B")


def test_long_context_eligibility():
    eligible = {a for a in ARCH_IDS
                if get_config(a).runs_long_context}
    assert eligible == {"zamba2_1p2b", "mixtral_8x7b", "rwkv6_3b"}


def test_moe_active_params():
    cfg = get_config("mixtral_8x7b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    # top-2 of 8 experts: active ≈ 2/8 of expert params + attn/embed
    assert active < 0.45 * total
    ds = get_config("deepseek_v3_671b")
    assert ds.active_param_count() < 0.12 * ds.param_count()
