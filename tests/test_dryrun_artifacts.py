"""Validates the multi-pod dry-run artifacts (run `repro.launch.dryrun`
first; skipped when artifacts are absent, e.g. on a fresh checkout)."""

import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")

ARCHS = 10
SHAPES = 4
MESHES = ("single", "multi")
EXPECTED_SKIPS = 7  # long_500k for pure full-attention archs


def load(mesh):
    files = sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json")))
    return [json.load(open(f)) for f in files]


@pytest.fixture(scope="module")
def cells():
    single, multi = load("single"), load("multi")
    if len(single) < ARCHS * SHAPES or len(multi) < ARCHS * SHAPES:
        pytest.skip("dry-run artifacts incomplete — run repro.launch.dryrun")
    return {"single": single, "multi": multi}


class TestCoverage:
    @pytest.mark.parametrize("mesh", MESHES)
    def test_all_40_cells_accounted(self, cells, mesh):
        cs = cells[mesh]
        assert len(cs) == ARCHS * SHAPES
        ok = [c for c in cs if c["status"] == "ok"]
        skipped = [c for c in cs if c["status"] == "skipped"]
        errors = [c for c in cs if c["status"] == "error"]
        assert not errors, [(c["arch"], c["shape"], c["error"])
                            for c in errors]
        assert len(skipped) == EXPECTED_SKIPS
        assert len(ok) == ARCHS * SHAPES - EXPECTED_SKIPS

    def test_skips_are_long_context_only(self, cells):
        for c in cells["single"]:
            if c["status"] == "skipped":
                assert c["shape"] == "long_500k"


class TestMeasurements:
    def test_single_pod_cells_have_roofline(self, cells):
        for c in cells["single"]:
            if c["status"] != "ok":
                continue
            r = c["roofline"]
            assert r["dominant"] in ("compute", "memory", "collective")
            assert r["compute_s"] > 0 and r["memory_s"] > 0
            assert 0 < r["compute_fraction"] <= 1.0
            # corrected HLO flops must cover the analytic 6ND/2ND model
            assert r["useful_flops_ratio"] <= 1.2, (c["arch"], c["shape"])

    def test_devices_counts(self, cells):
        for c in cells["single"]:
            if c["status"] == "ok":
                assert c["devices"] == 256
        for c in cells["multi"]:
            if c["status"] == "ok":
                assert c["devices"] == 512

    def test_multi_pod_memory_not_worse(self, cells):
        """2x devices must not increase per-device footprint materially
        (weak-scaling sanity).  Known exception, tracked in EXPERIMENTS.md
        §Perf: deepseek prefill_32k hits XLA's involuntary-replication
        fallback around the MoE dispatch gathers on the 3-axis mesh (1.92x);
        bound set above it to catch regressions beyond the known issue."""
        single = {(c["arch"], c["shape"]): c for c in cells["single"]
                  if c["status"] == "ok"}
        for c in cells["multi"]:
            if c["status"] != "ok":
                continue
            s = single.get((c["arch"], c["shape"]))
            if s is None:
                continue
            assert c["per_device_bytes"] <= s["per_device_bytes"] * 2.2, (
                c["arch"], c["shape"])
