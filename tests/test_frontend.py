"""GridFrontend: concurrent serving, cross-query coalescing, batched ticks,
mutation isolation, admission control — plus the thread-safety substrate
(atomic stats, locked LRU iteration).

Thread counts scale with ``FRONTEND_STRESS_THREADS`` (CI sets it high for
the threaded-stress leg; the default keeps local runs quick).
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core.blockstore import AtomicStats, LRUCache
from repro.core.frontend import (
    FrontendOverloadedError,
    FrontendStats,
    GridFrontend,
    QueryTimeoutError,
)
from repro.core.grid import GridSession
from repro.core.stats import (
    CountProgram,
    MeanProgram,
    VarianceProgram,
)
from test_grid import make_population, row_batch

STRESS = int(os.environ.get("FRONTEND_STRESS_THREADS", "8"))


def make_session(n=64, split_bytes=2000, **kw):
    return GridSession(make_population(n, split_bytes=split_bytes),
                       default_eta=8, **kw)


def fanout(n, fn):
    """Run ``fn(i)`` on n threads released by one barrier; re-raise the
    first worker exception in the caller."""
    barrier = threading.Barrier(n)
    errors = []

    def run(i):
        try:
            barrier.wait()
            fn(i)
        except BaseException as e:   # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=run, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]


class TestCoalescing:
    def test_barrier_identical_cold_queries_fold_once(self):
        """N concurrent identical queries: one execution, one fold per
        block, N-1 coalesce hits — the headline acceptance criterion."""
        s = make_session()
        plan = s.scan().map(MeanProgram()).reduce()
        n_regions = len(s.table.regions)
        assert n_regions > 1
        expect = s.table.column("img", "data").mean(axis=0)
        N = max(8, STRESS)
        futs = [None] * N
        with GridFrontend(s, workers=4, tick_ms=5.0) as fe:
            fanout(N, lambda i: futs.__setitem__(i, fe.submit(plan)))
            results = [f.result(timeout=120) for f in futs]
            stats = fe.stats.snapshot()
        for val, _rep in results:
            np.testing.assert_allclose(np.asarray(val), expect, atol=1e-5)
        assert stats.coalesce_hits >= N - 1
        assert stats.served == N
        # exactly one fold dispatch per block, however many clients asked
        store = s.blocks.stats.snapshot()
        assert store.folds == n_regions
        assert sum(s.engine.fold_path_counts.values()) == n_regions

    def test_warm_coalesce_zero_folds(self):
        s = make_session()
        plan = s.scan().map(MeanProgram()).reduce()
        with GridFrontend(s, workers=4, tick_ms=2.0) as fe:
            fe.query(plan, timeout=120)           # warm: result cache filled
            folds0 = s.blocks.stats.folds
            N = max(8, STRESS)
            futs = [None] * N
            fanout(N, lambda i: futs.__setitem__(i, fe.submit(plan)))
            for f in futs:
                f.result(timeout=120)
            assert fe.stats.coalesce_hits >= N - 1
        assert s.blocks.stats.folds == folds0

    def test_sequential_submissions_coalesce_until_mutation(self):
        """Completed flights are retained, so repeats coalesce without
        temporal overlap; a mutation clears the registry."""
        s = make_session()
        plan = s.scan().map(CountProgram()).reduce()
        with GridFrontend(s, workers=2, tick_ms=0.0) as fe:
            v1, _ = fe.query(plan, timeout=120)
            v2, _ = fe.query(plan, timeout=120)
            assert int(v1) == int(v2) == 64
            assert fe.stats.coalesce_hits >= 1
            scans_before = s.metrics.scans
            fe.upload(["zz1", "zz2"], row_batch(["zz1", "zz2"]))
            v3, _ = fe.query(plan, timeout=120)
            assert int(v3) == 66
            assert s.metrics.scans > scans_before   # re-executed, not replayed

    def test_no_coalesce_mode_executes_each_query(self):
        s = make_session()
        plan = s.scan().map(MeanProgram()).reduce()
        N = 6
        futs = [None] * N
        with GridFrontend(s, workers=4, tick_ms=2.0, coalesce=False) as fe:
            fanout(N, lambda i: futs.__setitem__(i, fe.submit(plan)))
            for f in futs:
                f.result(timeout=120)
            assert fe.stats.coalesce_hits == 0
            assert fe.stats.batch_merges == 0
            assert fe.stats.served == N
        assert s.metrics.scans == N     # every query its own execution
        # without the fold gate, concurrent misses may duplicate folds
        # (same content, wasted work — the control arm the bench measures)
        assert s.blocks.stats.folds >= len(s.table.regions)

    def test_fold_gate_single_flight(self):
        """The partial-level gate: concurrent misses on one pkey run the
        fold once; followers get the leader's result as coalesced."""
        s = make_session()
        with GridFrontend(s, workers=2) as fe:
            calls = []
            lock = threading.Lock()

            def slow_fold():
                with lock:
                    calls.append(1)
                time.sleep(0.2)
                return ("partial", None, False, False)

            N = max(8, STRESS)
            out = [None] * N
            fanout(N, lambda i: out.__setitem__(
                i, s.fold_gate(("pkey",), slow_fold)))
            assert len(calls) == 1
            assert all(res == ("partial", None, False, False)
                       for res, _ in out)
            assert sum(1 for _, coalesced in out if coalesced) == N - 1
            assert fe.stats.partial_coalesce_hits == N - 1


class TestBatchedTicks:
    def test_distinct_programs_merge_into_one_pass(self):
        s = make_session()
        t = s.table
        p1 = s.scan().map(VarianceProgram()).reduce()
        p2 = s.scan().map(CountProgram()).reduce()
        out = [None, None]
        with GridFrontend(s, workers=4, tick_ms=20.0) as fe:
            fanout(2, lambda i: out.__setitem__(
                i, fe.submit(p1 if i == 0 else p2)))
            (v1, rep1), (v2, rep2) = (out[0].result(120),
                                      out[1].result(120))
            assert fe.stats.batch_merges == 1
            assert fe.stats.batched_queries == 2
        np.testing.assert_allclose(
            np.asarray(v1["var"]), t.column("img", "data").var(axis=0),
            atol=1e-4)
        assert int(v2) == 64
        # both plans share one scan resolution and one fold pass
        assert rep1 is rep2
        assert s.metrics.scans == 1

    def test_grouped_plans_merge_and_split(self):
        s = make_session()
        t = s.table
        g1 = s.scan().group_by("idx:sex").map(MeanProgram()).reduce()
        g2 = s.scan().group_by("idx:sex").map(CountProgram()).reduce()
        out = [None, None]
        with GridFrontend(s, workers=4, tick_ms=20.0) as fe:
            fanout(2, lambda i: out.__setitem__(
                i, fe.submit(g1 if i == 0 else g2)))
            gr1, _ = out[0].result(120)
            gr2, _ = out[1].result(120)
            assert fe.stats.batch_merges == 1
        sex = t.column("idx", "sex")
        data = t.column("img", "data")
        np.testing.assert_array_equal(gr1.keys, np.unique(sex))
        for gi, k in enumerate(gr1.keys):
            np.testing.assert_allclose(
                np.asarray(gr1.values)[gi], data[sex == k].mean(axis=0),
                atol=1e-4)
            assert int(np.asarray(gr2.values)[gi]) == int((sex == k).sum())

    def test_multi_column_plans_merge_and_split(self):
        s = make_session()
        t = s.table
        cols = ["img:data", "idx:age"]
        m1 = s.scan().select(cols).map(MeanProgram()).reduce()
        m2 = s.scan().select(cols).map(CountProgram()).reduce()
        out = [None, None]
        with GridFrontend(s, workers=4, tick_ms=20.0) as fe:
            fanout(2, lambda i: out.__setitem__(
                i, fe.submit(m1 if i == 0 else m2)))
            mv1, _ = out[0].result(120)
            mv2, _ = out[1].result(120)
        assert set(mv1) == set(cols)
        np.testing.assert_allclose(
            np.asarray(mv1["idx:age"]), t.column("idx", "age").mean(),
            atol=1e-3)
        assert int(mv2["img:data"]) == 64

    def test_different_scans_do_not_merge(self):
        s = make_session()
        pa = s.scan(prefix=b"img0000").map(CountProgram()).reduce()
        pb = s.scan().map(CountProgram()).reduce()
        out = [None, None]
        with GridFrontend(s, workers=4, tick_ms=20.0) as fe:
            fanout(2, lambda i: out.__setitem__(
                i, fe.submit(pa if i == 0 else pb)))
            va, _ = out[0].result(120)
            vb, _ = out[1].result(120)
            assert fe.stats.batch_merges == 0
        assert int(va) == 10 and int(vb) == 64


class TestMutationIsolation:
    def test_queries_never_observe_partial_uploads(self):
        """Counts observed under interleaved 2-row uploads are always in
        the set of committed totals — the epoch write lock admits no
        torn reads."""
        s = make_session()
        rounds, batch = 4, 2
        valid = {64 + r * batch for r in range(rounds + 1)}
        observed = []
        obs_lock = threading.Lock()
        stop = threading.Event()

        with GridFrontend(s, workers=4, tick_ms=0.0) as fe:
            def reader(i):
                while not stop.is_set():
                    plan = s.scan().map(CountProgram()).reduce()
                    val, _ = fe.query(plan, timeout=120)
                    with obs_lock:
                        observed.append(int(val))

            threads = [threading.Thread(target=reader, args=(i,))
                       for i in range(max(4, STRESS // 2))]
            for t in threads:
                t.start()
            try:
                for r in range(rounds):
                    keys = [f"zz{r}_{j}" for j in range(batch)]
                    fe.upload(keys, row_batch(keys, seed=r + 10))
                    time.sleep(0.05)
            finally:
                stop.set()
                for t in threads:
                    t.join()
            assert fe.stats.mutations == rounds
        assert observed, "readers made no progress"
        assert set(observed) <= valid, (
            f"torn reads: {sorted(set(observed) - valid)}")
        final, _ = s.scan().map(CountProgram()).reduce().collect()
        assert int(final) == 64 + rounds * batch

    def test_mutation_drains_in_flight_query(self):
        """An upload issued while a slow query executes waits for it; the
        slow query's answer reflects the pre-mutation epoch."""
        s = make_session()
        entered = threading.Event()

        def slow_pred(cols):
            entered.set()
            time.sleep(0.4)
            return cols["age"] > -np.inf          # selects everything

        plan = s.scan().where(slow_pred, ["age"]).map(
            CountProgram()).reduce()
        with GridFrontend(s, workers=2, tick_ms=0.0) as fe:
            fut = fe.submit(plan)
            assert entered.wait(timeout=30)
            t0 = time.monotonic()
            fe.upload(["zz1"], row_batch(["zz1"]))
            drained = time.monotonic() - t0
            val, _ = fut.result(timeout=120)
        assert int(val) == 64            # pre-upload snapshot
        assert drained > 0.05            # the writer actually waited


class TestAdmission:
    def _slow_plan(self, s, delay=0.5, seed=0):
        def slow_pred(cols, _d=delay):
            time.sleep(_d)
            return cols["age"] > -np.inf

        return s.scan().where(slow_pred, ["age"]).map(
            CountProgram()).reduce()

    def test_backpressure_rejects_beyond_max_pending(self):
        s = make_session()
        with GridFrontend(s, workers=1, tick_ms=0.0,
                          max_pending=2) as fe:
            first = fe.submit(self._slow_plan(s))
            with pytest.raises(FrontendOverloadedError):
                for _ in range(4):
                    fe.submit(self._slow_plan(s))
            assert fe.stats.rejected >= 1
            first.result(timeout=120)

    def test_deadline_expires_queued_query(self):
        s = make_session()
        with GridFrontend(s, workers=1, tick_ms=0.0) as fe:
            blocker = fe.submit(self._slow_plan(s))
            doomed = fe.submit(s.scan().map(CountProgram()).reduce(),
                               deadline=0.01)
            with pytest.raises(QueryTimeoutError):
                doomed.result(timeout=120)
            assert fe.stats.timeouts == 1
            blocker.result(timeout=120)
            # the frontend still serves after a timeout
            val, _ = fe.query(s.scan().map(CountProgram()).reduce(),
                              timeout=120)
            assert int(val) == 64

    def test_deadline_enforced_during_execution(self):
        """A query whose deadline passes AFTER dispatch aborts at the
        next fold-gate entry instead of running to completion."""
        s = make_session()
        with GridFrontend(s, workers=1, tick_ms=0.0) as fe:
            doomed = fe.submit(self._slow_plan(s, delay=0.6),
                               deadline=0.15)
            with pytest.raises(QueryTimeoutError):
                doomed.result(timeout=120)
            assert fe.stats.timeouts == 1
            assert fe.stats.served == 0
            # aborted before folding a single block
            assert s.blocks.stats.folds == 0
            # the flight was released: the identical plan re-executes
            val, _ = fe.query(self._slow_plan(s, delay=0.0), timeout=120)
            assert int(val) == 64
            assert fe.stats.served == 1 and fe.stats.timeouts == 1

    def test_timed_out_sync_query_is_abandoned_once(self):
        """query(timeout=) that gives up must settle its task exactly
        once (as a timeout) and release the flight — the old behaviour
        left the task running and counted it ``served``."""
        s = make_session()
        with GridFrontend(s, workers=1, tick_ms=0.0) as fe:
            blocker = fe.submit(self._slow_plan(s))
            plan = s.scan().map(CountProgram()).reduce()
            with pytest.raises(QueryTimeoutError):
                fe.query(plan, timeout=0.05)
            assert fe.stats.timeouts == 1
            blocker.result(timeout=120)
            # resubmitting is NOT coalesced onto the abandoned flight
            val, _ = fe.query(s.scan().map(CountProgram()).reduce(),
                              timeout=120)
            assert int(val) == 64
            snap = fe.stats.snapshot()
            assert snap.served == 2          # blocker + the retry
            assert snap.failed == 1          # the abandoned task, once
            assert snap.timeouts == 1
            assert snap.served + snap.failed == snap.submitted

    def test_submit_after_close_raises(self):
        s = make_session()
        fe = GridFrontend(s, workers=1)
        fe.close()
        with pytest.raises(RuntimeError):
            fe.submit(s.scan().map(CountProgram()).reduce())
        assert s.fold_gate is None       # hook released

    def test_double_close_is_idempotent(self):
        s = make_session()
        fe = GridFrontend(s, workers=1)
        fe.query(s.scan().map(CountProgram()).reduce(), timeout=120)
        fe.close()
        fe.close()                       # second close: clean no-op
        assert s.fold_gate is None
        # and the context manager may wrap an already-closed frontend
        with fe:
            pass

    def test_close_drains_in_flight_work(self):
        """close() called while queries are executing and a mutation is
        queued behind them: everything submitted before the close
        resolves (no dangling futures), then the frontend shuts down."""
        s = make_session()
        fe = GridFrontend(s, workers=2, tick_ms=0.0)
        futs = [fe.submit(s.scan().map(CountProgram()).reduce())
                for _ in range(4)]
        done = threading.Event()

        def mutate():
            fe.upload(["zzclose"], row_batch(["zzclose"]))
            done.set()

        mut = threading.Thread(target=mutate)
        mut.start()
        fe.close()
        mut.join(timeout=120)
        assert done.is_set(), "mutation queued before close must complete"
        for f in futs:
            val, _rep = f.result(timeout=120)   # resolved, not abandoned
            assert int(val) in (64, 65)
        assert s.table.num_rows == 65
        snap = fe.stats.snapshot()
        assert snap.served == snap.submitted == 4
        assert snap.mutations == 1


class TestThreadSafetySubstrate:
    def test_lru_iteration_safe_under_concurrent_eviction(self):
        """keys()/values()/items() snapshots never raise while another
        thread churns the cache past its cap."""
        cache = LRUCache(32)
        stop = threading.Event()
        errors = []

        def churn():
            i = 0
            while not stop.is_set():
                cache.put(i % 100, i)
                cache.get((i * 7) % 100)
                i += 1

        def walk():
            try:
                while not stop.is_set():
                    for k, v in cache.items():
                        assert v is not None
                    list(cache.keys())
                    list(cache.values())
            except RuntimeError as e:    # "dict changed size" = the bug
                errors.append(e)

        threads = ([threading.Thread(target=churn) for _ in range(3)]
                   + [threading.Thread(target=walk) for _ in range(3)])
        for t in threads:
            t.start()
        time.sleep(0.5)
        stop.set()
        for t in threads:
            t.join()
        assert not errors

    def test_atomic_stats_exact_under_contention(self):
        stats = FrontendStats()
        N, per = max(8, STRESS), 500
        fanout(N, lambda i: [stats.inc(served=1, submitted=2)
                             for _ in range(per)])
        assert stats.served == N * per
        assert stats.submitted == 2 * N * per

    def test_atomic_stats_imax_monotone(self):
        stats = FrontendStats()
        fanout(8, lambda i: [stats.imax(queue_depth_peak=d)
                             for d in range(100)])
        assert stats.queue_depth_peak == 99

    def test_snapshot_is_consistent(self):
        """inc() batches two counters atomically; snapshot() never sees
        them apart."""
        stats = FrontendStats()
        stop = threading.Event()
        torn = []

        def bump():
            while not stop.is_set():
                stats.inc(served=1, submitted=1)

        def observe():
            while not stop.is_set():
                snap = stats.snapshot()
                if snap.served != snap.submitted:
                    torn.append((snap.served, snap.submitted))

        threads = ([threading.Thread(target=bump) for _ in range(4)]
                   + [threading.Thread(target=observe) for _ in range(2)])
        for t in threads:
            t.start()
        time.sleep(0.5)
        stop.set()
        for t in threads:
            t.join()
        assert not torn

    def test_blockstore_stats_snapshot(self):
        s = make_session()
        s.scan().map(CountProgram()).reduce().collect()
        snap = s.blocks.stats.snapshot()
        assert snap.folds == len(s.table.regions)
        # detached copy: live counters keep moving, the snapshot doesn't
        s.upload(["zz1"], row_batch(["zz1"]))
        s.scan().map(CountProgram()).reduce().collect()
        assert s.blocks.stats.folds > snap.folds


class TestFrontendStats:
    def test_latency_percentiles(self):
        stats = FrontendStats()
        assert stats.latency_percentiles() == (0.0, 0.0)
        for ms in range(1, 101):
            stats.record_latency(ms / 1000.0)
        p50, p99 = stats.latency_percentiles()
        assert 0.045 <= p50 <= 0.055
        assert 0.095 <= p99 <= 0.100

    def test_queue_depth_peak_observed(self):
        s = make_session()
        plan_a = s.scan().map(MeanProgram()).reduce()
        plan_b = s.scan(prefix=b"img0000").map(MeanProgram()).reduce()
        with GridFrontend(s, workers=1, tick_ms=50.0) as fe:
            fa, fb = fe.submit(plan_a), fe.submit(plan_b)
            fa.result(timeout=120)
            fb.result(timeout=120)
            assert fe.stats.queue_depth_peak >= 2
