"""Grid scheduler: straggler mitigation, failure handling, elastic joins."""

import numpy as np

from repro.core.balancer import NodeSpec, allocation_imbalance
from repro.core.placement import Placement
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.scheduler import GridScheduler
from repro.core.table import ColumnSpec, make_mip_table


def build(n_rows=256, n_nodes=4, seed=0):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=(2,),
        split_policy=HierarchicalSplitPolicy(max_region_bytes=int(80e6)),
    )
    t.upload(
        [f"r{i:05d}" for i in range(n_rows)],
        {"img": {"data": rng.normal(size=(n_rows, 2)).astype(np.float32)},
         "idx": {"size": rng.integers(6e6, 20e6, n_rows)}},
    )
    nodes = [NodeSpec(i, cores=1, mips=1.0) for i in range(n_nodes)]
    pl = Placement.from_strategy(t, nodes, "greedy")
    return t, pl


class TestStragglerMitigation:
    def test_sustained_straggler_triggers_rebalance(self):
        t, pl = build()
        sched = GridScheduler(pl, chunk_size=8, rebalance_threshold=0.2,
                              min_rounds_between_rebalance=1)
        ev = None
        # node 3 is 4x slower every round
        for _ in range(12):
            times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 4.0}
            ev = sched.observe_round(times) or ev
        assert ev is not None and ev.reason == "straggler"
        # regions shifted away from node 3
        loads = pl.node_bytes()
        assert loads[3] < loads[0]

    def test_no_rebalance_when_uniform(self):
        t, pl = build()
        sched = GridScheduler(pl, chunk_size=8, rebalance_threshold=0.2,
                              min_rounds_between_rebalance=1)
        for _ in range(6):
            ev = sched.observe_round({i: 1.0 for i in range(4)})
            assert ev is None


class TestFailureHandling:
    def test_failure_orphans_adopted(self):
        t, pl = build()
        rows_before = sum(pl.node_row_counts().values())
        sched = GridScheduler(pl, chunk_size=8)
        ev = sched.handle_failure([2])
        assert ev.reason == "failure"
        assert 2 not in {n.node_id for n in pl.nodes}
        # no rows lost, none on the dead node
        counts = pl.node_row_counts()
        assert sum(counts.values()) == rows_before
        assert set(counts) == {0, 1, 3}
        live_ids = {n.node_id for n in pl.nodes}
        assert set(pl.alloc.values()) <= live_ids

    def test_elastic_join_takes_load(self):
        t, pl = build(n_nodes=2)
        sched = GridScheduler(pl, chunk_size=8)
        before = max(pl.node_row_counts().values())
        ev = sched.handle_join([NodeSpec(7, cores=1, mips=2.0)])
        assert ev.reason == "elastic"
        counts = pl.node_row_counts()
        assert counts[7] > 0                      # newcomer got work
        assert max(counts.values()) < before      # peak load dropped
        # fast newcomer gets the largest share
        assert counts[7] == max(counts.values())


class TestPlanning:
    def test_makespan_estimate_decreases_after_rebalance(self):
        t, pl = build()
        sched = GridScheduler(pl, chunk_size=8, rebalance_threshold=0.1,
                              min_rounds_between_rebalance=1)
        for _ in range(8):
            sched.observe_round({0: 1.0, 1: 1.0, 2: 1.0, 3: 6.0})
        imb = allocation_imbalance(
            pl.alloc, t.region_bytes(),
            sched._current_nodes(),
        )
        assert imb < 0.6  # proportional-ish under the observed powers
