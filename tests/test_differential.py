"""Stateful differential harness: GridSession vs a plain-NumPy oracle.

Random interleavings of ``upload`` / ``remove`` / ``rebalance`` /
``scan().where().map().reduce()`` run against both the real backend (blocks,
layouts, plan caches, engine) and a dict-of-rows NumPy mirror; every
``.collect()``/``.stats()`` must agree, and after every step the harness
asserts the structural invariants:

- ``blocks_reused + blocks_transferred == blocks_total`` on every executed
  plan (the copy-on-write accounting can never leak or double-count a block);
- mutation epochs are monotone, advancing exactly when rows change;
- the table's region/rowkey invariants hold (strictly sorted keys, regions
  tile the keyspace).

The same :class:`DifferentialDriver` drives two entry points: a Hypothesis
``RuleBasedStateMachine`` (shrinking, CI profile in ``conftest.py``) and a
seeded random walk that needs no third-party package — the walk covers the
``>= 200`` interleaved steps the PR acceptance asks for even where
Hypothesis isn't installed.
"""

import os

import numpy as np
import pytest

from repro.core.faults import FaultInjector, FaultRule, RetryPolicy
from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import CountProgram, MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

try:
    from hypothesis import strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        invariant,
        rule,
    )
    HAVE_HYPOTHESIS = True
except ImportError:           # container without hypothesis: fallback only
    HAVE_HYPOTHESIS = False

PAYLOAD = (2, 3)
PREFIXES = "abcde"
#: small region threshold so the walk triggers organic splits (13 MB mean
#: logical row size -> a region splits after ~8 rows)
SPLIT_BYTES = int(8 * 13e6)


class DifferentialDriver:
    """One live GridSession + its NumPy oracle + the op vocabulary.

    ``session_kwargs`` overrides session construction — the spill-pressure
    variants pass tiny per-tier byte budgets plus a tmpdir spill dir, so
    the SAME op vocabulary and oracles run with blocks and partials
    constantly demoting through the tier chain."""

    def __init__(self, session_kwargs=None):
        self.table = make_mip_table(
            payload_shape=PAYLOAD,
            extra_index_columns=[ColumnSpec("age", (), np.float32),
                                 ColumnSpec("sex", (), np.int8)],
            split_policy=HierarchicalSplitPolicy(max_region_bytes=SPLIT_BYTES),
        )
        kwargs = dict(default_eta=4, block_cache_cap=32)
        kwargs.update(session_kwargs or {})
        self.session = GridSession(self.table, **kwargs)
        # oracle: rowkey -> {column: value}; ALL query semantics re-derived
        # from this dict with plain numpy
        self.rows = {}
        self.last_epoch = 0
        self.steps = 0

    # ------------------------------------------------------------------
    # oracle helpers
    # ------------------------------------------------------------------

    def oracle_keys(self, prefix=b"", start=None, stop=None):
        keys = [k for k in sorted(self.rows) if k.startswith(prefix)]
        if start is not None:
            keys = [k for k in keys if k >= start]
        if stop is not None:
            keys = [k for k in keys if k < stop]
        return keys

    def oracle_column(self, keys, col="img"):
        if not keys:
            shape = PAYLOAD if col == "img" else ()
            return np.empty((0,) + shape, np.float32)
        return np.stack([self.rows[k][col] for k in keys]).astype(np.float32)

    def _batch(self, keys, rng):
        n = len(keys)
        return {
            "img": {"data": rng.normal(size=(n,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                    "age": rng.uniform(4, 80, n).astype(np.float32),
                    "sex": rng.integers(0, 2, n).astype(np.int8)},
        }

    def _key_universe(self, rng, n):
        picks = rng.integers(0, len(PREFIXES), n), rng.integers(0, 40, n)
        return sorted({f"{PREFIXES[p]}{i:02d}".encode()
                       for p, i in zip(*picks)})

    # ------------------------------------------------------------------
    # mutations (applied to both worlds, then cross-checked)
    # ------------------------------------------------------------------

    def op_upload(self, seed, mode="skip"):
        rng = np.random.default_rng(seed)
        keys = self._key_universe(rng, int(rng.integers(1, 5)))
        data = self._batch(keys, rng)
        written = self.session.upload(keys, data, on_duplicate=mode)
        expect = 0
        for i, k in enumerate(keys):
            if k in self.rows and mode == "skip":
                continue
            self.rows[k] = {"img": data["img"]["data"][i],
                            "age": data["idx"]["age"][i],
                            "sex": data["idx"]["sex"][i]}
            expect += 1
        assert written == expect, (written, expect, keys)
        self._after_mutation(changed=written > 0)

    def op_remove_key(self, seed):
        rng = np.random.default_rng(seed)
        if not self.rows:
            return
        key = sorted(self.rows)[int(rng.integers(0, len(self.rows)))]
        removed = self.session.remove(rowkey=key)
        assert removed == 1, key
        del self.rows[key]
        self._after_mutation(changed=True)

    def op_remove_range(self, seed):
        rng = np.random.default_rng(seed)
        a, b = self._key_universe(rng, 2)[:2], None
        start = a[0]
        stop = a[-1] if len(a) > 1 and a[-1] > a[0] else None
        doomed = self.oracle_keys(start=start, stop=stop)
        removed = self.session.remove(start=start, stop=stop)
        assert removed == len(doomed), (start, stop, removed, doomed)
        for k in doomed:
            del self.rows[k]
        self._after_mutation(changed=removed > 0)

    def op_rebalance(self, seed):
        moved = self.session.rebalance(tolerance=0.05)
        # single-device runs never move; multi-device may. Either way the
        # verbs must stay consistent afterwards:
        self._after_mutation(changed=bool(moved))

    # ------------------------------------------------------------------
    # queries (differential checks)
    # ------------------------------------------------------------------

    def op_query_full(self, seed):
        res, rep = self.session.run(MeanProgram())
        self._check_report(rep)
        keys = self.oracle_keys()
        if keys:
            np.testing.assert_allclose(
                np.asarray(res), self.oracle_column(keys).mean(0), atol=3e-4)
        else:
            assert np.all(np.isfinite(np.asarray(res)))
        # the fold-engine acceptance invariant, pinned inside the walk: an
        # immediate repeat at an unchanged table folds ZERO payload rows
        res2, rep2 = self.session.run(MeanProgram())
        self._check_report(rep2)
        q2 = rep2.query
        assert q2.rows_folded == 0, q2
        assert q2.partials_reused == q2.partials_total, q2
        np.testing.assert_array_equal(np.asarray(res), np.asarray(res2))

    def op_query_prefix(self, seed):
        rng = np.random.default_rng(seed)
        prefix = PREFIXES[int(rng.integers(0, len(PREFIXES)))].encode()
        q = (self.session.scan(prefix=prefix).map(MeanProgram())
             .map(VarianceProgram()).map(CountProgram()).reduce())
        (mean, var, count), rep = q.collect()
        self._check_report(rep)
        keys = self.oracle_keys(prefix=prefix)
        assert rep.query.rows_selected == len(keys)
        # the fold itself must count exactly the masked-in slots — any
        # padding/row-mask bug in the block assembly shows up here
        assert int(count) == len(keys)
        if keys:
            ref = self.oracle_column(keys)
            np.testing.assert_allclose(np.asarray(mean), ref.mean(0),
                                       atol=3e-4)
            np.testing.assert_allclose(np.asarray(var["var"]), ref.var(0),
                                       atol=2e-3)

    def op_query_predicate(self, seed):
        rng = np.random.default_rng(seed)
        lo = float(rng.uniform(4, 60))
        pred = age_sex_predicate(lo, lo + 25, int(rng.integers(0, 2)))
        res, rep = self.session.run_where(pred, MeanProgram(),
                                          ["age", "sex"])
        self._check_report(rep)
        keys = self.oracle_keys()
        if keys:
            mask = pred({"age": self.oracle_column(keys, "age"),
                         "sex": self.oracle_column(keys, "sex")})
            assert rep.query.rows_selected == int(mask.sum())
            if mask.any():
                np.testing.assert_allclose(
                    np.asarray(res),
                    self.oracle_column(keys)[mask].mean(0), atol=3e-4)
        else:
            assert rep.query.rows_selected == 0

    def op_collect_rows(self, seed):
        rng = np.random.default_rng(seed)
        prefix = PREFIXES[int(rng.integers(0, len(PREFIXES)))].encode()
        (keys, cols), rep = (self.session.scan(prefix=prefix)
                             .select("img:data").collect())
        want = self.oracle_keys(prefix=prefix)
        assert [bytes(k) for k in keys] == want
        np.testing.assert_array_equal(cols["img:data"],
                                      self.oracle_column(want))

    def _check_grouped(self, res, keys, col="img"):
        """One GroupedResult (mean, count) vs the NumPy groupby oracle."""
        vals = self.oracle_column(keys, col)
        sexes = self.oracle_column(keys, "sex").astype(np.int8)
        want = {int(k): vals[sexes == k] for k in np.unique(sexes)}
        assert [int(k) for k in res.keys] == sorted(want)
        mean, count = res.values
        for g, k in enumerate(res.keys):
            rows = want[int(k)]
            assert int(np.asarray(count)[g]) == len(rows)
            np.testing.assert_allclose(np.asarray(mean)[g], rows.mean(0),
                                       atol=3e-4)

    def op_query_grouped(self, seed):
        """Grouped stats vs a NumPy groupby oracle, plus the acceptance
        invariants: repeat folds zero rows, grouping never multiplies
        gathers (each gathered block is gathered once, however many
        groups)."""
        rng = np.random.default_rng(seed)
        prefix = b"" if rng.integers(0, 2) else \
            PREFIXES[int(rng.integers(0, len(PREFIXES)))].encode()

        def q():
            scan = (self.session.scan(prefix=prefix) if prefix
                    else self.session.scan())
            return (scan.select("img:data").group_by("idx:sex")
                    .map(MeanProgram()).map(CountProgram()).reduce())

        res, rep = q().collect()
        self._check_report(rep)
        keys = self.oracle_keys(prefix=prefix)
        assert rep.query.num_groups == len(
            set(int(self.rows[k]["sex"]) for k in keys))
        # one pass: every gathered block was gathered exactly once
        assert rep.query.gather_count <= max(rep.query.partials_total, 0)
        self._check_grouped(res, keys)
        # acceptance: immediate repeat on the clean epoch folds ZERO rows
        res2, rep2 = q().collect()
        self._check_report(rep2)
        assert rep2.query.rows_folded == 0, rep2.query
        assert rep2.query.partials_reused == rep2.query.partials_total
        for a, b in zip(res.values, res2.values):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def op_query_grouped_multicol(self, seed):
        """Multi-column grouped plan: every program × every column in one
        pass, each column matching its own groupby oracle."""
        res, rep = (self.session.scan()
                    .select(["img:data", "idx:age"]).group_by("idx:sex")
                    .map(MeanProgram()).map(CountProgram())
                    .reduce().collect())
        self._check_report(rep)
        keys = self.oracle_keys()
        assert set(res) == {"img:data", "idx:age"}
        self._check_grouped(res["img:data"], keys, "img")
        self._check_grouped(res["idx:age"], keys, "age")

    # ------------------------------------------------------------------
    # invariants
    # ------------------------------------------------------------------

    def _check_report(self, rep):
        q = rep.query
        q.check_block_invariant()    # reused + transferred == total
        q.check_partial_invariant()  # all-reused ⟹ zero rows folded, etc.
        assert q.regions_scanned + q.regions_pruned == len(self.table.regions)
        assert rep.epoch == self.session.epoch

    def _after_mutation(self, changed: bool):
        epoch = self.session.epoch
        if changed:
            assert epoch == self.last_epoch + 1, "epochs advance one-by-one"
        else:
            assert epoch == self.last_epoch, "no-op mutations keep the epoch"
        self.last_epoch = epoch

    def check_state(self):
        assert self.session.epoch >= self.last_epoch
        assert self.table.num_rows == len(self.rows)
        self.table.check_invariants()
        blocks = self.session.blocks
        s = blocks.stats.snapshot()
        # a gather is followed by a device transfer (fold path), a
        # host-only retrieve read (fetch_host), or a host-side serve of a
        # block too big for the device tier — never silently dropped
        assert s.hits + s.transfers + s.host_reads + s.host_serves \
            >= s.gathers
        # per-tier byte gauges must equal a from-scratch recount of what
        # the blocks actually hold, across every evict/demote/promote/
        # rebalance interleaving the walk produced
        dev = host = disk = 0
        for b in blocks._blocks.values():
            if b.device is not None:
                dev += b.device_nbytes
            if b.host is not None and not b.host_mmap:
                host += b.nbytes
            if b.spill_path is not None:
                disk += b.spill_nbytes
        for _path, sz, _td in blocks._spilled_partials.values():
            disk += sz
        assert s.device_bytes == dev, (s.device_bytes, dev)
        assert s.host_bytes == host, (s.host_bytes, host)
        assert s.disk_bytes == disk, (s.disk_bytes, disk)
        # budgets are hard ceilings between operations
        if blocks.device_budget is not None:
            assert dev <= blocks.device_budget
        if blocks.host_budget is not None:
            assert host <= blocks.host_budget
        if blocks.disk_budget is not None:
            assert disk <= blocks.disk_budget
        assert blocks.resident_nbytes() == dev + host

    OPS = ("upload", "upload_overwrite", "remove_key", "remove_range",
           "rebalance", "query_full", "query_prefix", "query_predicate",
           "collect_rows", "query_grouped", "query_grouped_multicol")

    def apply(self, op: str, seed: int):
        if op == "upload":
            self.op_upload(seed)
        elif op == "upload_overwrite":
            self.op_upload(seed, mode="overwrite")
        elif op == "remove_key":
            self.op_remove_key(seed)
        elif op == "remove_range":
            self.op_remove_range(seed)
        elif op == "rebalance":
            self.op_rebalance(seed)
        elif op == "query_full":
            self.op_query_full(seed)
        elif op == "query_prefix":
            self.op_query_prefix(seed)
        elif op == "query_predicate":
            self.op_query_predicate(seed)
        elif op == "collect_rows":
            self.op_collect_rows(seed)
        elif op == "query_grouped":
            self.op_query_grouped(seed)
        elif op == "query_grouped_multicol":
            self.op_query_grouped_multicol(seed)
        else:                            # pragma: no cover
            raise AssertionError(op)
        self.steps += 1
        self.check_state()


# ----------------------------------------------------------------------
# entry point 1: seeded random walk (no third-party deps; always runs)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("walk_seed", [0, 1, 2])
def test_differential_random_walk(walk_seed):
    """>= 70 interleaved steps per seed (210 across the matrix), weighted
    toward mutations early (grow state) and queries throughout."""
    drv = DifferentialDriver()
    rng = np.random.default_rng(walk_seed)
    ops = list(DifferentialDriver.OPS)
    weights = np.array([4, 2, 2, 1, 1, 2, 3, 2, 2, 2, 1], dtype=float)
    weights /= weights.sum()
    for _ in range(70):
        op = rng.choice(ops, p=weights)
        drv.apply(str(op), int(rng.integers(0, 2**31)))
    assert drv.steps == 70
    # the walk must actually have exercised the reuse machinery
    assert drv.session.blocks.stats.hits > 0
    assert drv.session.blocks.stats.gathers > 0


def _spill_kwargs(tmpdir, device_budget=256):
    """Byte budgets tiny enough that the walk's blocks/partials constantly
    demote: payload blocks run tens-to-hundreds of bytes (24 B/row), so a
    256 B device tier host-serves big blocks and demotes the rest, 2 KiB
    of host RAM forces disk spill, and a bounded disk tier exercises
    spill-file drops.  ``prefetch=False`` keeps the walk single-threaded
    so ``check_state``'s exact gauge recount can't race a background
    promotion (the prefetcher has its own deterministic tests)."""
    return dict(device_budget=device_budget, host_budget=2048,
                disk_budget=1 << 20, partial_budget=4096,
                spill_dir=str(tmpdir.join("spill")), prefetch=False)


@pytest.mark.parametrize("walk_seed", [0, 1])
def test_differential_random_walk_under_spill(walk_seed, tmpdir):
    """The SAME differential walk with forced tier pressure: every query
    result stays exact and every per-tier byte gauge stays truthful while
    blocks and partials demote/promote through the chain."""
    drv = DifferentialDriver(session_kwargs=_spill_kwargs(tmpdir))
    rng = np.random.default_rng(walk_seed)
    ops = list(DifferentialDriver.OPS)
    weights = np.array([4, 2, 2, 1, 1, 2, 3, 2, 2, 2, 1], dtype=float)
    weights /= weights.sum()
    # CI's memory-constrained leg lengthens the walk (SPILL_WALK_STEPS)
    # to churn many more demote/spill/promote transitions per seed
    for _ in range(int(os.environ.get("SPILL_WALK_STEPS", "40"))):
        op = rng.choice(ops, p=weights)
        drv.apply(str(op), int(rng.integers(0, 2**31)))
    s = drv.session.blocks.stats.snapshot()
    # the pressure must actually have moved payloads between tiers
    assert s.demotions + s.spills + s.spill_drops + s.host_serves > 0, s
    drv.session.close()
    assert drv.session.blocks.tier_bytes()["disk"] == 0


class FaultWalkDriver(DifferentialDriver):
    """The differential vocabulary under fault injection.

    Two acceptance assertions relax — everything else (numeric equality
    vs the oracle, block/partial invariants, exact tier-gauge recounts)
    stays bit-strict:

    - repeats may fold rows: an injected spill corruption legitimately
      forces a lossless re-derive, so the "repeat folds zero" pin becomes
      "repeat is bit-equal";
    - epochs may advance outside mutations: a device loss mid-query
      quarantines the owner and re-homes its regions, which is an epoch
      by design.
    """

    def op_query_full(self, seed):
        res, rep = self.session.run(MeanProgram())
        self._check_report(rep)
        keys = self.oracle_keys()
        if keys:
            np.testing.assert_allclose(
                np.asarray(res), self.oracle_column(keys).mean(0), atol=3e-4)
        res2, rep2 = self.session.run(MeanProgram())
        self._check_report(rep2)
        np.testing.assert_array_equal(np.asarray(res), np.asarray(res2))

    def op_query_grouped(self, seed):
        rng = np.random.default_rng(seed)
        prefix = b"" if rng.integers(0, 2) else \
            PREFIXES[int(rng.integers(0, len(PREFIXES)))].encode()
        scan = (self.session.scan(prefix=prefix) if prefix
                else self.session.scan())
        res, rep = (scan.select("img:data").group_by("idx:sex")
                    .map(MeanProgram()).map(CountProgram()).reduce()
                    .collect())
        self._check_report(rep)
        self._check_grouped(res, self.oracle_keys(prefix=prefix))

    def _after_mutation(self, changed: bool):
        assert self.session.epoch >= self.last_epoch
        self.last_epoch = self.session.epoch

    def _check_report(self, rep):
        q = rep.query
        q.check_block_invariant()
        q.check_partial_invariant()
        assert q.regions_scanned + q.regions_pruned == len(self.table.regions)
        self.last_epoch = self.session.epoch


def fault_walk_rules():
    """The PR-acceptance fault mix: spill corruption on both sides of the
    disk tier, transient fabric/device flakiness, and fold stragglers."""
    return (
        FaultRule(site="device_put", kind="transient", p=0.05),
        FaultRule(site="gather", kind="transient", p=0.03),
        FaultRule(site="spill_read", kind="corrupt", p=0.5),
        FaultRule(site="spill_read", kind="truncate", p=0.15),
        FaultRule(site="spill_write", kind="delete", p=0.25),
        FaultRule(site="fold", kind="delay", p=0.02, delay_s=0.001),
    )


@pytest.mark.parametrize("walk_seed", [3, 7])
def test_differential_random_walk_under_faults(walk_seed, tmpdir):
    """The spill-pressure walk with an adversarial seeded fault schedule:
    corrupted and deleted spill files, flaky transfers and gathers, fold
    stragglers.  Every query result must still match the NumPy oracle
    exactly and every tier gauge must still recount exactly — faults are
    absorbed (retry, re-derive), never surfaced and never silently
    miscounted."""
    inj = FaultInjector(rules=fault_walk_rules(), seed=walk_seed)
    kwargs = _spill_kwargs(tmpdir)
    # tighter-than-spill-walk budgets: blocks are tens of bytes, so disk
    # traffic (the corruption surface) needs a near-empty host tier
    kwargs.update(host_budget=256, partial_budget=512,
                  fault_injector=inj,
                  retry_policy=RetryPolicy(max_attempts=4, base_delay_s=1e-4))
    drv = FaultWalkDriver(session_kwargs=kwargs)
    rng = np.random.default_rng(walk_seed)
    ops = list(DifferentialDriver.OPS)
    weights = np.array([4, 2, 2, 1, 1, 2, 3, 2, 2, 2, 1], dtype=float)
    weights /= weights.sum()
    for _ in range(int(os.environ.get("FAULT_WALK_STEPS", "70"))):
        op = rng.choice(ops, p=weights)
        drv.apply(str(op), int(rng.integers(0, 2**31)))
    s = drv.session.blocks.stats.snapshot()
    # the schedule must actually have bitten, and every bite recovered
    assert s.faults_injected > 0
    assert s.faults_injected == inj.faults_injected
    assert s.retries > 0, "transients must have been retried"
    assert s.spill_corruptions > 0, "a mangled spill must have been caught"
    assert s.spill_recoveries > 0, "a caught corruption must have re-derived"
    drv.session.close()
    assert drv.session.blocks.tier_bytes()["disk"] == 0


# ----------------------------------------------------------------------
# entry point 2: Hypothesis stateful machine (shrinks counterexamples)
# ----------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    class GridDifferentialMachine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.drv = DifferentialDriver()

        seeds = st.integers(min_value=0, max_value=2**31 - 1)

        @rule(seed=seeds)
        def upload(self, seed):
            self.drv.op_upload(seed)

        @rule(seed=seeds)
        def upload_overwrite(self, seed):
            self.drv.op_upload(seed, mode="overwrite")

        @rule(seed=seeds)
        def remove_key(self, seed):
            self.drv.op_remove_key(seed)

        @rule(seed=seeds)
        def remove_range(self, seed):
            self.drv.op_remove_range(seed)

        @rule(seed=seeds)
        def rebalance(self, seed):
            self.drv.op_rebalance(seed)

        @rule(seed=seeds)
        def query_full(self, seed):
            self.drv.op_query_full(seed)

        @rule(seed=seeds)
        def query_prefix(self, seed):
            self.drv.op_query_prefix(seed)

        @rule(seed=seeds)
        def query_predicate(self, seed):
            self.drv.op_query_predicate(seed)

        @rule(seed=seeds)
        def collect_rows(self, seed):
            self.drv.op_collect_rows(seed)

        @rule(seed=seeds)
        def query_grouped(self, seed):
            self.drv.op_query_grouped(seed)

        @rule(seed=seeds)
        def query_grouped_multicol(self, seed):
            self.drv.op_query_grouped_multicol(seed)

        @invariant()
        def state_consistent(self):
            self.drv.check_state()

    class SpillDifferentialMachine(GridDifferentialMachine):
        """The same rule vocabulary under forced tier pressure: tiny byte
        budgets + a private spill dir, so Hypothesis shrinks any
        interleaving where demote/promote/spill breaks a result or a
        gauge."""

        def __init__(self):
            RuleBasedStateMachine.__init__(self)
            import tempfile
            self._spill_root = tempfile.mkdtemp(prefix="grid-diff-spill-")
            self.drv = DifferentialDriver(session_kwargs=dict(
                device_budget=256, host_budget=2048, disk_budget=1 << 20,
                partial_budget=4096, spill_dir=self._spill_root,
                prefetch=False))

        def teardown(self):
            self.drv.session.close()

    # step count / example budget come from the ci/dev profiles registered
    # in conftest.py — no override here, or the profile knob goes dead
    TestGridDifferential = GridDifferentialMachine.TestCase
    TestGridDifferentialSpill = SpillDifferentialMachine.TestCase
