"""Fused Pallas fold kernel: one HBM pass per block for the grouped CSE
shared-accumulator pool.

The PR acceptance oracles live here: the kernel matches the float64 NumPy
oracle within fp32 accumulation tolerance across dtypes (bf16/f32/i32
rows), group counts {1, 7, 64} and ragged row counts hitting the pow2
padding; NaN/Inf in masked-off rows never poison accumulators; the
engine's pallas fold path is bitwise-compatible (within fp32 tolerance)
with the XLA fold for grouped AND ungrouped CSE folds; ineligible fold
signatures fall back to XLA; pallas fold executables stay keyed on the
pow2 row bucket and are chunk-free (η never enters the key); and the gid
block cache makes dirty-region re-folds skip re-densifying group ids.

Runs entirely in Pallas interpret mode on CPU (``fold_interpret=True`` /
the op's ``interpret=True`` default).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # CI installs hypothesis; bare containers skip
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core.grid import GridSession
from repro.core.mapreduce import MapReduceEngine
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import (
    CountProgram,
    FusedProgram,
    GroupedProgram,
    HistogramProgram,
    MeanProgram,
    MomentsProgram,
    VarianceProgram,
)
from repro.core.table import ColumnSpec, make_mip_table
from repro.kernels.fused_fold import (
    fused_fold,
    fused_fold_numpy,
    kernel_hbm_bytes,
    max_groups_for_vmem,
)
from repro.utils import make_mesh

rng = np.random.default_rng(421)

PAYLOAD = (3, 4)
CSE_MEMBERS = (MeanProgram(), VarianceProgram(), MomentsProgram())


def assert_pool_close(got, want, rtol=1e-4, atol=1e-3):
    assert set(got) == set(want)
    for n in want:
        np.testing.assert_allclose(np.asarray(got[n], np.float64),
                                   np.asarray(want[n], np.float64),
                                   rtol=rtol, atol=atol, err_msg=n)


# ----------------------------------------------------------------------
# kernel vs the float64 NumPy oracle
# ----------------------------------------------------------------------

class TestKernelVsOracle:
    @pytest.mark.parametrize("G", [1, 7, 64])
    @pytest.mark.parametrize("R,shape", [
        (1, (8,)), (13, (5,)), (64, (12, 11)), (300, (130,)),
    ])
    def test_f32_grouped_matches_oracle(self, R, shape, G):
        x = rng.normal(size=(R,) + shape).astype(np.float32)
        m = rng.random(R) > 0.25
        g = rng.integers(0, G, R).astype(np.int32)
        got = fused_fold(jnp.asarray(x), jnp.asarray(m), jnp.asarray(g),
                         num_groups=G)
        want = fused_fold_numpy(x, m, g, num_groups=G)
        assert got["count"].shape == (G,)
        assert got["s1"].shape == (G,) + shape
        assert_pool_close(got, want)

    @pytest.mark.parametrize("G", [1, 7])
    def test_bf16_rows(self, G):
        x32 = rng.normal(size=(50, 24)).astype(np.float32)
        x = jnp.asarray(x32).astype(jnp.bfloat16)
        m = rng.random(50) > 0.3
        g = rng.integers(0, G, 50).astype(np.int32)
        got = fused_fold(x, jnp.asarray(m), jnp.asarray(g), num_groups=G)
        want = fused_fold_numpy(np.asarray(x, np.float32), m, g,
                                num_groups=G)
        # bf16 rows: ~3 significand digits; s4 amplifies to ~1e-1
        assert_pool_close(got, want, rtol=5e-2, atol=2e-1)
        np.testing.assert_array_equal(np.asarray(got["count"]),
                                      want["count"])

    @pytest.mark.parametrize("G", [1, 7])
    def test_i32_rows(self, G):
        x = rng.integers(-9, 10, size=(40, 16)).astype(np.int32)
        m = rng.random(40) > 0.5
        g = rng.integers(0, G, 40).astype(np.int32)
        got = fused_fold(jnp.asarray(x), jnp.asarray(m), jnp.asarray(g),
                         num_groups=G)
        # small ints: fp32 accumulation is exact
        assert_pool_close(got, fused_fold_numpy(x, m, g, num_groups=G),
                          rtol=0, atol=0)

    def test_defaults_are_ungrouped_unmasked(self):
        x = rng.normal(size=(20, 8)).astype(np.float32)
        got = fused_fold(jnp.asarray(x))
        assert_pool_close(got, fused_fold_numpy(x))

    def test_accumulator_subset(self):
        x = rng.normal(size=(33, 9)).astype(np.float32)
        m = rng.random(33) > 0.4
        got = fused_fold(jnp.asarray(x), jnp.asarray(m),
                         names=("count", "s1", "s2"))
        assert set(got) == {"count", "s1", "s2"}
        assert_pool_close(
            got, fused_fold_numpy(x, m, names=("count", "s1", "s2")))

    def test_empty_groups_stay_zero(self):
        x = rng.normal(size=(16, 4)).astype(np.float32)
        g = np.zeros(16, np.int32)          # everything lands in group 0
        got = fused_fold(jnp.asarray(x), None, jnp.asarray(g), num_groups=5)
        np.testing.assert_array_equal(np.asarray(got["count"])[1:], 0)
        np.testing.assert_array_equal(np.asarray(got["s2"])[1:], 0)

    def _check_ragged(self, R, F, G, seed):
        """Ragged R/F exercise the pad-to-tile path: padded rows carry
        zero mask, padded groups receive no rows — the oracle never sees
        any of it."""
        r = np.random.default_rng(seed)
        x = r.normal(size=(R, F)).astype(np.float32)
        m = r.random(R) > 0.5
        g = r.integers(0, G, R).astype(np.int32)
        got = fused_fold(jnp.asarray(x), jnp.asarray(m), jnp.asarray(g),
                         num_groups=G)
        want = fused_fold_numpy(x, m, g, num_groups=G)
        assert_pool_close(got, want, rtol=1e-3, atol=1e-2)
        np.testing.assert_array_equal(np.asarray(got["count"]),
                                      want["count"])

    @pytest.mark.parametrize("R,F,G,seed", [
        (13, 5, 1, 0), (255, 129, 7, 1), (257, 3, 64, 2), (9, 200, 7, 3),
    ])
    def test_ragged_shapes_fixed(self, R, F, G, seed):
        self._check_ragged(R, F, G, seed)

    if HAVE_HYPOTHESIS:
        @given(
            R=st.integers(1, 300),
            F=st.integers(1, 200),
            G=st.sampled_from([1, 7, 64]),
            seed=st.integers(0, 2**31 - 1),
        )
        @settings(max_examples=25, deadline=None)
        def test_property_ragged_shapes(self, R, F, G, seed):
            self._check_ragged(R, F, G, seed)

    def test_nan_inf_in_masked_rows_never_poison(self):
        """Regression: masked rows are ZEROED BEFORE the power raises.
        A masked row full of NaN/Inf must leave every accumulator finite
        and equal to the fold of the valid rows alone (0·NaN = NaN, so a
        multiply-by-mask kernel would fail this)."""
        x = rng.normal(size=(24, 10)).astype(np.float32)
        m = np.ones(24, bool)
        m[[3, 11, 17]] = False
        x[3] = np.nan
        x[11] = np.inf
        x[17, ::2] = -np.inf
        g = rng.integers(0, 3, 24).astype(np.int32)
        got = fused_fold(jnp.asarray(x), jnp.asarray(m), jnp.asarray(g),
                         num_groups=3)
        for n, a in got.items():
            assert bool(jnp.isfinite(a).all()), n
        assert_pool_close(got, fused_fold_numpy(x, m, g, num_groups=3))

    def test_all_masked(self):
        x = rng.normal(size=(32, 16)).astype(np.float32)
        got = fused_fold(jnp.asarray(x), jnp.asarray(np.zeros(32, bool)))
        for a in got.values():
            np.testing.assert_array_equal(np.asarray(a), 0)


# ----------------------------------------------------------------------
# engine dispatch: eligibility, fallback, executable keying
# ----------------------------------------------------------------------

def interp_engine(**kw):
    return MapReduceEngine(make_mesh((1,), ("data",)),
                           fold_interpret=True, **kw)


class TestFoldPath:
    def test_cse_programs_take_pallas(self):
        eng = interp_engine()
        for p in CSE_MEMBERS + (FusedProgram(CSE_MEMBERS),
                                GroupedProgram(FusedProgram(CSE_MEMBERS),
                                               num_groups=5)):
            assert eng.fold_path(p, np.float32, 0) == "pallas", p

    def test_fallback_without_interpret_off_tpu(self):
        eng = MapReduceEngine(make_mesh((1,), ("data",)))
        if jax.default_backend() != "tpu":
            assert eng.fold_path(MeanProgram(), np.float32) == "xla"

    def test_fallback_when_forced_xla(self):
        eng = interp_engine(fold_impl="xla")
        assert eng.fold_path(MeanProgram(), np.float32) == "xla"

    def test_fallback_outside_the_pool(self):
        eng = interp_engine()
        # private members / non-pool accumulators have no kernel form
        assert eng.fold_path(HistogramProgram(), np.float32) == "xla"
        assert eng.fold_path(CountProgram(), np.float32) == "xla"
        assert eng.fold_path(
            FusedProgram(CSE_MEMBERS + (CountProgram(),)),
            np.float32) == "xla"
        # non-fp32 accumulation keeps the reference fold
        assert eng.fold_path(MeanProgram(acc_dtype=jnp.float64),
                             np.float32) == "xla"

    def test_fallback_complex_dtype(self):
        assert interp_engine().fold_path(
            MeanProgram(), np.complex64) == "xla"

    def test_fallback_above_vmem_group_budget(self):
        eng = interp_engine()
        cap = max_groups_for_vmem(("count", "s1"))
        assert cap > 0
        prog = GroupedProgram(MeanProgram(), num_groups=cap + 1)
        assert eng.fold_path(prog, np.float32, cap + 1) == "xla"
        assert eng.fold_path(prog, np.float32, cap) == "pallas"

    def test_unknown_fold_impl_rejected(self):
        with pytest.raises(ValueError):
            MapReduceEngine(make_mesh((1,), ("data",)), fold_impl="cuda")

    def test_pallas_executables_are_chunk_free_and_bucketed(self):
        """η never enters the pallas fold key, and distinct row counts in
        one pow2 bucket share the executable — only a bucket change (or a
        G change) compiles."""
        eng = interp_engine()
        p = MeanProgram()
        n0 = eng.compile_count

        def fold(rows, eta):
            blk = jnp.asarray(
                rng.normal(size=(rows,) + PAYLOAD).astype(np.float32))
            return eng.fold_block(p, blk, None, eta, PAYLOAD, np.float32)

        fold(33, 4)                      # bucket 64: compile
        fold(61, 7)                      # same bucket, other η + rows
        fold(40, 2)
        assert eng.compile_count == n0 + 1
        fold(100, 4)                     # bucket 128: one more
        assert eng.compile_count == n0 + 2
        assert eng.fold_path_counts["pallas"] == 4


# ----------------------------------------------------------------------
# engine differential: pallas fold ≡ xla fold (grouped and ungrouped)
# ----------------------------------------------------------------------

class TestEngineDifferential:
    PROGRAMS = [
        MeanProgram(),
        VarianceProgram(),
        MomentsProgram(),
        FusedProgram(CSE_MEMBERS),
        GroupedProgram(MeanProgram(), num_groups=5),
        GroupedProgram(FusedProgram(CSE_MEMBERS), num_groups=5),
    ]

    @pytest.mark.parametrize(
        "program", PROGRAMS, ids=lambda p: str(p.cache_key()[0]))
    def test_pallas_equals_xla(self, program):
        grouped = isinstance(program, GroupedProgram)
        G = program.num_groups if grouped else 0
        blocks = [rng.normal(size=(r,) + PAYLOAD).astype(np.float32)
                  for r in (5, 33, 1, 64)]
        masks = [rng.random(len(b)) > 0.3 for b in blocks]
        gids = [rng.integers(0, max(1, G), len(b)).astype(np.int32)
                for b in blocks]
        results = {}
        for impl in ("pallas", "xla"):
            eng = interp_engine(fold_impl=impl)
            ps = []
            for b, m, g in zip(blocks, masks, gids):
                assert eng.fold_path(program, np.float32, G) == impl
                ps.append(eng.fold_block(
                    program, jnp.asarray(b), jnp.asarray(m), 4,
                    PAYLOAD, np.float32,
                    gids=jnp.asarray(g) if grouped else None,
                    num_groups=G))
            results[impl] = eng.merge_finalize(program, ps, PAYLOAD,
                                               np.float32)
            assert eng.fold_path_counts[impl] == len(blocks)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-4, atol=1e-3),
            results["pallas"], results["xla"])


# ----------------------------------------------------------------------
# session level: grouped pipeline on the kernel fold path
# ----------------------------------------------------------------------

def make_table(regions=("a", "b", "c", "d"), per=10, seed=0, sites=5):
    r = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("site", (), np.int32)],
        split_policy=HierarchicalSplitPolicy(max_region_bytes=10**18),
        presplit_keys=list(regions)[1:],
    )
    keys = [f"{g}{i:04d}" for g in regions for i in range(per)]
    n = len(keys)
    t.upload(keys, {
        "img": {"data": r.normal(size=(n,) + PAYLOAD).astype(np.float32)},
        "idx": {"size": r.integers(6_000_000, 20_000_001, n),
                "age": r.uniform(4, 80, n).astype(np.float32),
                "site": r.integers(0, sites, n).astype(np.int32)}})
    return t


def pallas_session(t, **kw):
    return GridSession(t, default_eta=4, fold_impl="pallas",
                       fold_interpret=True, **kw)


class TestSessionDifferential:
    def grouped(self, s):
        return (s.scan().select("img:data").group_by("idx:site")
                .map(MeanProgram()).map(VarianceProgram()).reduce())

    def test_grouped_session_pallas_equals_xla(self):
        res = {}
        for impl in ("pallas", "xla"):
            s = GridSession(make_table(), default_eta=4, fold_impl=impl,
                            fold_interpret=(impl == "pallas"))
            r, _ = self.grouped(s).collect()
            assert s.engine.fold_path_counts[impl] > 0
            assert s.engine.fold_path_counts[
                "xla" if impl == "pallas" else "pallas"] == 0
            res[impl] = r
        assert list(res["pallas"].keys) == list(res["xla"].keys)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=1e-4, atol=1e-3),
            list(res["pallas"].values), list(res["xla"].values))

    def test_grouped_session_matches_numpy_groupby(self):
        t = make_table(seed=3)
        s = pallas_session(t)
        res, rep = self.grouped(s).collect()
        data, sites = t.column("img", "data"), t.column("idx", "site")
        mean, var = res.values
        for g, k in enumerate(res.keys):
            want = data[sites == k]
            np.testing.assert_allclose(np.asarray(mean)[g], want.mean(0),
                                       rtol=1e-4, atol=1e-3)
            np.testing.assert_allclose(np.asarray(var["var"])[g],
                                       want.var(0), rtol=1e-3, atol=1e-3)
        rep.query.check_block_invariant()
        rep.query.check_partial_invariant()

    def test_pallas_and_xla_partials_cache_separately(self):
        """Flipping fold_impl mid-session must re-fold, not merge fp32
        pools accumulated in different orders — the partial key carries
        the implementation."""
        t = make_table()
        s = pallas_session(t)
        s.run(MeanProgram())                 # full pallas partials for a..d
        assert s.engine.fold_path_counts["pallas"] == len(t.regions)
        # regions a+b are fully covered by [a, c): the range query's
        # partial keys match the full-table ones EXCEPT the impl — after
        # the flip nothing may be served from the pallas pool
        s.engine.fold_impl = "xla"
        _, rep = (s.scan(start="a", stop="c")
                  .map(MeanProgram()).collect())
        assert rep.query.partials_reused == 0
        assert rep.query.rows_folded == 20
        assert s.engine.fold_path_counts["xla"] == 2
        # flip back: a fresh range finds the original pallas partials
        s.engine.fold_impl = "pallas"
        _, rep2 = (s.scan(start="a", stop="b")
                   .map(MeanProgram()).collect())
        assert rep2.query.partials_reused == 1
        assert rep2.query.rows_folded == 0

    def test_repeat_grouped_stats_folds_zero_rows(self):
        s = pallas_session(make_table())
        self.grouped(s).stats()
        _, rep = self.grouped(s).collect()
        assert rep.query.rows_folded == 0
        assert rep.query.partials_reused == rep.query.partials_total


class TestGidCache:
    def grouped(self, s):
        return (s.scan().select("img:data").group_by("idx:site")
                .map(MeanProgram()).reduce())

    def test_dirty_region_refold_skips_redensify(self):
        """Satellite acceptance: after a single-region mutation that keeps
        the group universe stable, the re-fold densifies gids ONLY for the
        dirty region — every clean region's gid block is either untouched
        (partial reused) or served from the cache."""
        t = make_table()
        s = pallas_session(t)
        self.grouped(s).stats()
        st0 = s.blocks.stats
        assert st0.gid_builds == len(t.regions)
        key = b"b0003"
        cols = {c: s.retrieve("idx", c, rowkey=key)[1]
                for c in ("age", "site", "size")}
        b0 = st0.gid_builds
        s.upload([key], {
            "img": {"data": np.zeros((1,) + PAYLOAD, np.float32)},
            "idx": cols}, on_duplicate="overwrite")
        _, rep = self.grouped(s).collect()
        dirty = t.regions.region_for(key)
        assert rep.query.rows_folded == dirty.num_rows(t.keys)
        assert s.blocks.stats.gid_builds == b0 + 1   # only the dirty region
        assert s.blocks.gid_count == len(t.regions)

    def test_gid_blocks_shared_across_programs(self):
        """A second grouped plan over the same key column re-folds its own
        partials but serves every gid block from the cache."""
        t = make_table()
        s = pallas_session(t)
        self.grouped(s).stats()
        b0, h0 = s.blocks.stats.gid_builds, s.blocks.stats.gid_hits
        (s.scan().select("img:data").group_by("idx:site")
         .map(MomentsProgram()).reduce().stats())
        assert s.blocks.stats.gid_builds == b0
        assert s.blocks.stats.gid_hits == h0 + len(t.regions)

    def test_clear_partials_drops_gid_blocks(self):
        s = pallas_session(make_table())
        self.grouped(s).stats()
        assert s.blocks.gid_count > 0
        s.blocks.clear_partials()
        assert s.blocks.gid_count == 0


# ----------------------------------------------------------------------
# analytic cost: one-HBM-pass contract
# ----------------------------------------------------------------------

class TestCostModel:
    def test_kernel_bytes_near_one_payload_pass(self):
        """The kernel's HBM traffic is the payload once plus O(R) sidecars
        and O(G·F) write-back — for a realistic block it must stay within
        a few percent of the bare payload size."""
        R, F = 4096, 3072
        payload = R * F * 4
        b = kernel_hbm_bytes(R, F, 4, ("count", "s1", "s2", "s3", "s4"),
                             num_groups=7)
        assert payload < b < 1.05 * payload

    def test_vmem_budget_positive_and_monotone(self):
        full = max_groups_for_vmem()
        assert full > 0
        assert max_groups_for_vmem(("count", "s1")) > full
