"""Approximate-sketch programs: error bounds vs exact NumPy oracles, the
exact merge law (bit-identical under any merge order/strategy/chunking),
spill serialization, and end-to-end session integration.

The acceptance oracles of the sketch PR live here and in
test_multidevice.py:

- every sketch estimate is within its DOCUMENTED bound of the float64
  exact answer from :mod:`repro.core.ref` (ε·n / δ for count-min, the
  dyadic rank bound for quantiles, standard-error multiples for HLL);
- merged sketch state is bit-identical however the partials are merged
  (sequential funnel, balanced tree, random permutation, engine funnel)
  and however the rows are chunked — int32 sums and maxes carry no
  rounding, so the merge law is exact, not approximate;
- sketch partials round-trip the BlockStore's ``.npz`` spill path
  bit-identically;
- a repeat sketch query on a clean epoch folds zero rows (block-partial
  caching), and grouped sketch queries match per-group oracles.
"""

import io

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ref
from repro.core.grid import GridSession
from repro.core.mapreduce import (
    MapReduceEngine,
    MapReduceProgram,
    partial_from_host,
    partial_to_host,
)
from repro.core.stats import (
    CountMinProgram,
    FusedProgram,
    GroupedProgram,
    GroupedResult,
    HyperLogLogProgram,
    MeanProgram,
    QuantileSketchProgram,
)
from repro.utils import make_mesh

from test_group_by import PAYLOAD, make_table

SKETCHES = [
    CountMinProgram(depth=4, width=1024, seed=11),
    HyperLogLogProgram(p=11, seed=12),
    # dense mode: U = 2048 <= depth * width -> exact bucket counts
    QuantileSketchProgram(lo=-4.0, hi=4.0, log2_universe=11, depth=4,
                          width=1024, probes=(0.25, 0.5, 0.9), seed=13),
    # count-min mode: U = 65536 > depth * width -> hashed dyadic levels
    QuantileSketchProgram(lo=-4.0, hi=4.0, log2_universe=16, depth=4,
                          width=1024, probes=(0.5,), seed=14),
]


def quantile_rank_err(qs, items, quantiles, targets):
    """Distance from each target rank to the exact rank interval of the
    returned value widened by ±1 bucket (the documented value
    quantization); what remains is the sketch's rank error."""
    res = qs.value_resolution()
    v = np.asarray(quantiles, np.float64)
    below, _ = ref.rank_interval(items, v - res)
    _, at_or_below = ref.rank_interval(items, v + res)
    return ref.interval_distance(targets, below, at_or_below)


def fold_items(program, items, eta=256, zero_shape=(1,)):
    """Reference fold: chunk ``items`` (as [n, 1] rows) through map_chunk
    + merge, all rows valid."""
    rows = np.asarray(items, np.float32).reshape(-1, 1)
    acc = program.zero(zero_shape, np.float32)
    for start in range(0, len(rows), eta):
        chunk = rows[start:start + eta]
        valid = jnp.ones(len(chunk), bool)
        acc = program.merge(acc, program.map_chunk(jnp.asarray(chunk), valid))
    return acc


def assert_trees_bitequal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        xa, ya = np.asarray(x), np.asarray(y)
        assert xa.dtype == ya.dtype
        np.testing.assert_array_equal(xa, ya)


# ----------------------------------------------------------------------
# parameter validation
# ----------------------------------------------------------------------

class TestValidation:
    def test_countmin_rejects_bad_params(self):
        with pytest.raises(ValueError):
            CountMinProgram(depth=0)
        with pytest.raises(ValueError):
            CountMinProgram(width=1000)          # not a power of two

    def test_hll_rejects_bad_precision(self):
        with pytest.raises(ValueError):
            HyperLogLogProgram(p=3)
        with pytest.raises(ValueError):
            HyperLogLogProgram(p=17)

    def test_quantile_rejects_bad_params(self):
        with pytest.raises(ValueError):
            QuantileSketchProgram(lo=1.0, hi=0.0)
        with pytest.raises(ValueError):
            QuantileSketchProgram(probes=(0.0,))
        with pytest.raises(ValueError):
            QuantileSketchProgram(probes=())
        with pytest.raises(ValueError):
            QuantileSketchProgram(width=100)

    def test_cache_keys_distinguish_params(self):
        assert CountMinProgram(seed=1).cache_key() != \
            CountMinProgram(seed=2).cache_key()
        assert QuantileSketchProgram(probes=(0.5,)).cache_key() != \
            QuantileSketchProgram(probes=(0.9,)).cache_key()


# ----------------------------------------------------------------------
# error bounds vs the exact float64 oracles (repro.core.ref)
# ----------------------------------------------------------------------

class TestCountMinBounds:
    def test_point_estimates_within_documented_bound(self):
        rng = np.random.default_rng(0)
        # zipf-flavored discrete distribution: few heavy, many light items
        universe = np.arange(200, dtype=np.float32)
        weights = 1.0 / np.arange(1, 201) ** 1.2
        items = rng.choice(universe, size=8000, p=weights / weights.sum())
        cm = CountMinProgram(depth=4, width=1024, seed=11)
        res = jax.tree.map(np.asarray, cm.finalize(fold_items(cm, items)))
        uniq, counts = ref.exact_frequencies(items)
        est = cm.estimate(res, uniq)
        assert int(res["n"]) == len(items)
        # one-sided: never an undercount
        assert (est >= counts).all()
        eps_n, delta = cm.error_bound(len(items))
        # with delta ~ e^-4 per query, allow the documented failure rate
        # (deterministic for the fixed seed; currently zero violations)
        violations = int((est - counts > eps_n).sum())
        assert violations <= max(1, int(np.ceil(2 * delta * len(uniq))))

    def test_heavy_hitters_superset_of_exact(self):
        rng = np.random.default_rng(1)
        items = np.concatenate([
            np.full(3000, 7.0, np.float32),         # ~43% heavy
            np.full(1500, 13.0, np.float32),        # ~21% heavy
            rng.normal(size=2500).astype(np.float32)])
        rng.shuffle(items)
        cm = CountMinProgram(depth=4, width=1024, seed=3)
        res = jax.tree.map(np.asarray, cm.finalize(fold_items(cm, items)))
        exact = ref.exact_heavy_hitters(items, phi=0.2)
        got = cm.heavy_hitters(res, np.unique(items), phi=0.2)
        got_vals = {v for v, _ in got}
        for v, _ in exact:                          # no true HH is missed
            assert v in got_vals
        # estimates stay within the overcount bound for the reported set
        eps_n, _ = cm.error_bound(len(items))
        exact_map = dict(zip(*map(list, ref.exact_frequencies(items))))
        for v, e in got:
            assert e <= exact_map[np.float32(v)] + eps_n


class TestHLLBounds:
    @pytest.mark.parametrize("n_distinct", [100, 2000, 20000])
    def test_relative_error_within_std_error_multiple(self, n_distinct):
        rng = np.random.default_rng(n_distinct)
        uniq = rng.normal(size=n_distinct).astype(np.float32)
        # duplicate every item ~3x: cardinality must ignore multiplicity
        items = np.repeat(uniq, rng.integers(1, 5, n_distinct))
        hll = HyperLogLogProgram(p=12, seed=5)
        res = jax.tree.map(np.asarray, hll.finalize(fold_items(hll, items)))
        true = ref.exact_distinct(items)
        rel_err = abs(float(res["estimate"]) - true) / true
        assert rel_err <= 4 * hll.std_error(), (rel_err, hll.std_error())

    def test_small_range_linear_counting(self):
        items = np.arange(40, dtype=np.float32)
        hll = HyperLogLogProgram(p=12, seed=5)
        res = jax.tree.map(np.asarray, hll.finalize(fold_items(hll, items)))
        # linear counting is near-exact far below m
        assert abs(float(res["estimate"]) - 40) <= 2

    def test_empty_fold_estimates_zero(self):
        hll = HyperLogLogProgram(p=10)
        res = hll.finalize(hll.zero((1,), np.float32))
        assert float(res["estimate"]) == 0.0


class TestQuantileBounds:
    @pytest.mark.parametrize("mode", ["dense", "cm"])
    @pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
    def test_rank_error_within_documented_bound(self, dist, mode):
        rng = np.random.default_rng(hash(dist) % 2**31)
        n = 6000
        if dist == "uniform":
            items = rng.uniform(-4, 4, n)
        elif dist == "lognormal":
            items = np.clip(rng.lognormal(0.0, 0.7, n) - 2.0, -4, 3.999)
        else:
            items = np.concatenate([rng.normal(-2, 0.3, n // 2),
                                    rng.normal(2.5, 0.5, n - n // 2)])
        items = np.clip(items, -4, 3.999).astype(np.float32)
        log2_u = 11 if mode == "dense" else 16
        qs = QuantileSketchProgram(lo=-4.0, hi=4.0, log2_universe=log2_u,
                                   depth=4, width=1024,
                                   probes=(0.1, 0.5, 0.9, 0.99), seed=13)
        assert qs.dense == (mode == "dense")
        res = jax.tree.map(np.asarray, qs.finalize(fold_items(qs, items)))
        assert int(res["n"]) == n
        # the target rank must sit within the documented rank bound of the
        # returned value's exact rank interval (±1 bucket of quantization)
        targets = np.ceil(np.asarray(qs.probes) * n)
        err = quantile_rank_err(qs, items, res["quantiles"], targets)
        bound = qs.rank_error_bound(n) + 1
        assert (err <= bound).all(), (err, bound, res["quantiles"])
        # and the host-side rank estimator obeys its own contract against
        # the quantized-bucket oracle: exact when dense, one-sided
        # overcount within the documented bound in count-min mode
        ranks = qs.rank_estimate(res, res["quantiles"])
        b_items = qs._buckets(ref.canonical_items(items), np)
        b_query = qs._buckets(np.asarray(res["quantiles"], np.float32), np)
        true_ranks = np.array([(b_items < bq).sum() for bq in b_query])
        assert (ranks >= true_ranks).all()          # never an undercount
        assert (ranks - true_ranks <= qs.rank_error_bound(n) + 1e-9).all()

    def test_values_close_to_exact_quantiles(self):
        rng = np.random.default_rng(2)
        items = rng.uniform(-4, 4, 8000).astype(np.float32)
        qs = SKETCHES[2]
        res = jax.tree.map(np.asarray, qs.finalize(fold_items(qs, items)))
        exact = ref.exact_quantiles(items, qs.probes)
        # uniform density ~ n/(hi-lo) per unit: rank bound translates to a
        # value tolerance of bound/density + one bucket
        density = len(items) / 8.0
        tol = (qs.rank_error_bound(len(items)) + 1) / density \
            + 2 * qs.value_resolution()
        np.testing.assert_allclose(res["quantiles"], exact, atol=tol)

    def test_empty_fold_is_nan(self):
        qs = SKETCHES[2]
        res = qs.finalize(qs.zero((1,), np.float32))
        assert np.isnan(np.asarray(res["quantiles"])).all()


# ----------------------------------------------------------------------
# the merge law: bit-identical under any merge order / chunking
# ----------------------------------------------------------------------

class TestMergeLaw:
    @pytest.mark.parametrize("program", SKETCHES,
                             ids=lambda p: type(p).__name__)
    def test_merge_order_invariance_bitwise(self, program):
        rng = np.random.default_rng(7)
        items = rng.normal(size=3000).astype(np.float32).clip(-3.9, 3.9)
        # 13 uneven partials
        cuts = np.sort(rng.choice(np.arange(1, 3000), 12, replace=False))
        parts = [fold_items(program, c)
                 for c in np.split(items, cuts)]

        def funnel(ps):
            acc = ps[0]
            for p in ps[1:]:
                acc = program.merge(acc, p)
            return acc

        def tree(ps):
            ps = list(ps)
            while len(ps) > 1:
                ps = [program.merge(ps[i], ps[i + 1])
                      if i + 1 < len(ps) else ps[i]
                      for i in range(0, len(ps), 2)]
            return ps[0]

        perm = list(rng.permutation(len(parts)))
        merged = [funnel(parts), tree(parts),
                  funnel([parts[i] for i in perm])]
        for other in merged[1:]:
            assert_trees_bitequal(merged[0], other)
            assert_trees_bitequal(program.finalize(merged[0]),
                                  program.finalize(other))

    @pytest.mark.parametrize("program", SKETCHES,
                             ids=lambda p: type(p).__name__)
    def test_chunk_size_invariance_bitwise(self, program):
        rng = np.random.default_rng(8)
        items = rng.normal(size=1111).astype(np.float32).clip(-3.9, 3.9)
        a = fold_items(program, items, eta=64)
        b = fold_items(program, items, eta=333)
        assert_trees_bitequal(a, b)

    @pytest.mark.parametrize("program", SKETCHES,
                             ids=lambda p: type(p).__name__)
    def test_engine_funnel_matches_pairwise_merge(self, program):
        """The engine's stacked additive funnel (per-leaf sum/max) must
        agree bit-for-bit with the program's own pairwise merge."""
        rng = np.random.default_rng(9)
        items = rng.normal(size=900).astype(np.float32).clip(-3.9, 3.9)
        parts = [fold_items(program, c) for c in np.split(items, 3)]
        mesh = make_mesh((1,), ("data",))
        eng = MapReduceEngine(mesh, merge_strategy="funnel")
        got = eng.merge_finalize(program, parts, (1,), np.float32)
        want = program.finalize(
            program.merge(program.merge(parts[0], parts[1]), parts[2]))
        assert_trees_bitequal(got, want)

    def test_grouped_sketch_merge_respects_max(self):
        """A grouped fused sketch stack merges HLL registers by max and
        everything else by sum — per leaf, through GroupedProgram."""
        hll = HyperLogLogProgram(p=8, seed=1)
        fused = FusedProgram((MeanProgram(), hll))
        gp = GroupedProgram(fused, 2)
        rng = np.random.default_rng(3)
        rows = jnp.asarray(rng.normal(size=(8, 1)).astype(np.float32))
        gmask = jnp.asarray(np.arange(8) % 2 == 0).reshape(1, 8)
        gmask = jnp.concatenate([gmask, ~gmask], axis=0)
        a = gp.map_chunk(rows, gmask)
        b = gp.map_chunk(rows[::-1], gmask)
        m = gp.merge(a, b)
        regs_a = np.asarray(a["private"][0]["regs"])
        regs_b = np.asarray(b["private"][0]["regs"])
        np.testing.assert_array_equal(
            np.asarray(m["private"][0]["regs"]),
            np.maximum(regs_a, regs_b))
        (dt, pool), = m["shared"].items()
        np.testing.assert_array_equal(
            np.asarray(pool["count"]),
            np.asarray(a["shared"][dt]["count"])
            + np.asarray(b["shared"][dt]["count"]))


class TestMergeOpsProtocol:
    def test_default_is_all_sum(self):
        p = MeanProgram()
        assert p.merge_ops_for(p.zero((1,), np.float32)) is None

    def test_hll_declares_max_per_leaf(self):
        hll = HyperLogLogProgram(p=6)
        assert hll.merge_ops_for(hll.zero((1,), np.float32)) == ["max"]

    def test_fused_composes_private_ops_before_shared(self):
        fused = FusedProgram((MeanProgram(), HyperLogLogProgram(p=6),
                              CountMinProgram(depth=2, width=64)))
        z = fused.zero((1,), np.float32)
        ops = fused.merge_ops_for(z)
        leaves = jax.tree.leaves(z)
        assert len(ops) == len(leaves)
        # exactly one max leaf: the HLL registers
        assert ops.count("max") == 1
        # and it lines up with the int32 register leaf
        max_leaf = leaves[ops.index("max")]
        assert max_leaf.shape == (64,) and max_leaf.dtype == jnp.int32

    def test_grouped_delegates_to_fused(self):
        fused = FusedProgram((MeanProgram(), HyperLogLogProgram(p=6)))
        gp = GroupedProgram(fused, 3)
        z = gp.zero((1,), np.float32)
        assert gp.merge_ops_for(z) == fused.merge_ops_for(z)

    def test_engine_rejects_wrong_length_ops(self):
        class Bad(MapReduceProgram):
            additive = True

            def zero(self, row_shape, dtype):
                return {"a": jnp.zeros((), jnp.int32),
                        "b": jnp.zeros((), jnp.int32)}

            def map_chunk(self, rows, valid):
                return self.zero((), None)

            def merge(self, a, b):
                return jax.tree.map(jnp.add, a, b)

            def finalize(self, p):
                return p

            def merge_ops_for(self, partial):
                return ["max"]                    # wrong length

        eng = MapReduceEngine(make_mesh((1,), ("data",)),
                              merge_strategy="funnel")
        bad = Bad()
        parts = [bad.zero((), None), bad.zero((), None)]
        with pytest.raises(ValueError, match="merge_ops_for"):
            eng.merge_finalize(bad, parts, (1,), np.float32)


# ----------------------------------------------------------------------
# spill serialization: partials round-trip the .npz path bit-identically
# ----------------------------------------------------------------------

class TestSpillRoundTrip:
    @pytest.mark.parametrize("program", SKETCHES,
                             ids=lambda p: type(p).__name__)
    def test_npz_round_trip_bitwise(self, program):
        rng = np.random.default_rng(5)
        items = rng.normal(size=500).astype(np.float32).clip(-3.9, 3.9)
        partial = fold_items(program, items)
        leaves, treedef = partial_to_host(partial)
        buf = io.BytesIO()
        np.savez(buf, *leaves)
        buf.seek(0)
        loaded = np.load(buf)
        back = partial_from_host([loaded[k] for k in loaded.files], treedef)
        assert_trees_bitequal(partial, back)
        assert_trees_bitequal(program.finalize(partial),
                              program.finalize(jax.tree.map(
                                  jnp.asarray, back)))


# ----------------------------------------------------------------------
# session integration: caching, grouping, merge-strategy invariance
# ----------------------------------------------------------------------

def sketch_plan(s, **kw):
    return (s.scan().select("img:data")
            .map(CountMinProgram(depth=4, width=1024, seed=21))
            .map(HyperLogLogProgram(p=10, seed=22))
            .map(QuantileSketchProgram(lo=-5.0, hi=5.0, log2_universe=11,
                                       depth=4, width=1024,
                                       probes=(0.5, 0.95), seed=23))
            .reduce())


class TestSessionIntegration:
    def test_sketches_match_oracles_end_to_end(self):
        t = make_table(per=32, seed=6)
        s = GridSession(t, default_eta=8)
        (cm_res, hll_res, q_res), rep = sketch_plan(s).collect()
        data = t.column("img", "data")
        n_items = data.size
        # count-min: n exact, estimates bounded
        cm = CountMinProgram(depth=4, width=1024, seed=21)
        assert int(np.asarray(cm_res["n"])) == n_items
        uniq, counts = ref.exact_frequencies(data)
        est = cm.estimate(jax.tree.map(np.asarray, cm_res), uniq)
        assert (est >= counts).all()
        # hll: within 4 standard errors of the exact distinct count
        hll = HyperLogLogProgram(p=10, seed=22)
        true_d = ref.exact_distinct(data)
        assert abs(float(np.asarray(hll_res["estimate"])) - true_d) \
            <= 4 * hll.std_error() * true_d
        # quantiles: rank bound against the exact rank interval
        qs = QuantileSketchProgram(lo=-5.0, hi=5.0, log2_universe=11,
                                   depth=4, width=1024,
                                   probes=(0.5, 0.95), seed=23)
        targets = np.ceil(np.asarray(qs.probes) * n_items)
        err = quantile_rank_err(qs, data, np.asarray(q_res["quantiles"]),
                                targets)
        assert (err <= qs.rank_error_bound(n_items) + 1).all()

    def test_repeat_sketch_query_folds_zero_rows(self):
        """Acceptance: repeat sketch queries on a clean epoch reuse every
        cached block partial and fold zero payload rows."""
        t = make_table(per=16, seed=7)
        s = GridSession(t, default_eta=8)
        r1 = sketch_plan(s).stats()
        assert r1.query.rows_folded == t.num_rows
        r2 = sketch_plan(s).stats()              # fresh plan object
        assert r2.query.rows_folded == 0, r2.query
        assert r2.query.partials_reused == r2.query.partials_total

    def test_warm_and_cold_results_bitwise_identical(self):
        t = make_table(per=16, seed=8)
        s = GridSession(t, default_eta=8)
        cold, _ = sketch_plan(s).collect()
        warm, _ = sketch_plan(s).collect()
        assert_trees_bitequal(cold, warm)
        # and a completely fresh session agrees bit-for-bit too
        s2 = GridSession(t, default_eta=8)
        fresh, _ = sketch_plan(s2).collect()
        assert_trees_bitequal(cold, fresh)

    def test_eta_invariance_bitwise(self):
        t = make_table(per=20, seed=9)
        s = GridSession(t, default_eta=4)
        a, _ = sketch_plan(s).collect(eta=4)
        b, _ = sketch_plan(s).collect(eta=16)
        assert_trees_bitequal(a, b)

    def test_grouped_sketches_match_per_group_oracles(self):
        t = make_table(per=24, seed=10, sites=3)
        s = GridSession(t, default_eta=8)
        hll = HyperLogLogProgram(p=10, seed=31)
        qs = QuantileSketchProgram(lo=-5.0, hi=5.0, log2_universe=11,
                                   depth=4, width=1024, probes=(0.5,),
                                   seed=32)
        res, rep = (s.scan().select("img:data").group_by("idx:site")
                    .map(hll).map(qs).reduce().collect())
        data = t.column("img", "data")
        sites = t.column("idx", "site")
        assert isinstance(res, GroupedResult)
        hll_res, q_res = res.values
        for g, k in enumerate(res.keys):
            sub = data[sites == k]
            true_d = ref.exact_distinct(sub)
            est = float(np.asarray(hll_res["estimate"])[g])
            assert abs(est - true_d) <= 4 * hll.std_error() * true_d
            n_g = sub.size
            err = quantile_rank_err(qs, sub,
                                    np.asarray(q_res["quantiles"])[g],
                                    np.ceil(0.5 * n_g))
            assert (err <= qs.rank_error_bound(n_g) + 1).all()

    def test_grouped_sketch_composite_key(self):
        t = make_table(per=24, seed=11, sites=2)
        s = GridSession(t, default_eta=8)
        hll = HyperLogLogProgram(p=10, seed=41)
        res, _ = (s.scan().select("img:data")
                  .group_by(["idx:site", "idx:sex"])
                  .map(hll).reduce().collect())
        data = t.column("img", "data")
        site, sex = t.column("idx", "site"), t.column("idx", "sex")
        for g, k in enumerate(res.keys):
            sub = data[(site == k[0]) & (sex == k[1])]
            true_d = ref.exact_distinct(sub)
            est = float(np.asarray(res.values["estimate"])[g])
            assert abs(est - true_d) <= 4 * hll.std_error() * max(true_d, 1)

    def test_mutation_refolds_dirty_and_matches_fresh_session(self):
        """Differential: after a mutation, the incrementally-maintained
        sketch (cached partials + one dirty re-fold) must be bit-identical
        to a from-scratch session — the merge law end to end."""
        t = make_table(per=16, seed=12)
        s = GridSession(t, default_eta=8)
        sketch_plan(s).collect()
        rng = np.random.default_rng(13)
        s.upload([b"b0003"], {
            "img": {"data": rng.normal(size=(1,) + PAYLOAD)
                    .astype(np.float32)},
            "idx": {"size": np.array([7_000_000]),
                    "age": np.array([33.0], np.float32),
                    "sex": np.array([1], np.int8),
                    "site": np.array([0], np.int32)}},
            on_duplicate="overwrite")
        warm, rep = sketch_plan(s).collect()
        assert 0 < rep.query.rows_folded < t.num_rows
        fresh, _ = sketch_plan(GridSession(t, default_eta=8)).collect()
        assert_trees_bitequal(warm, fresh)
