"""Tests for the paper's eq. (1)-(8) chunk-size model."""

import math

import pytest

from repro.core.chunk_model import (
    ChunkModel,
    ChunkModelParams,
    PAPER_PARAMS,
    TPU_V5E_PARAMS,
    TierCostModel,
    tpu_chunk_params,
)


class TestPaperReproduction:
    """Validates the model against the paper's own claims (§2.4.3, §3.2)."""

    def test_eta_window_matches_paper(self):
        lo, hi = ChunkModel(PAPER_PARAMS).eta_bounds()
        # paper assesses eta in [30, 160]: the upper bound is exact
        # (mem/SizeBig = 160); the lower bound the paper rounds up from
        # max(#img*SizeSmall/mem, #img/core) = max(9.7, 23.0) = 23.
        assert hi == 160
        assert lo == math.ceil(5153 / 224) == 24

    def test_optimal_eta_in_paper_band(self):
        eta, _ = ChunkModel(PAPER_PARAMS).optimal_eta(metric="wall")
        assert 50 <= eta <= 62  # paper: optimum observed at 50-60

    def test_resource_time_flat_beyond_80(self):
        # paper: "when chunk size more than 80, the resource time becomes
        # similar" — the curve must flatten: relative change < 5% from 80->160
        cm = ChunkModel(PAPER_PARAMS)
        r80 = cm.resource_time(80)["total"]
        r160 = cm.resource_time(160)["total"]
        assert abs(r160 - r80) / r80 < 0.05

    def test_wall_time_u_shape(self):
        cm = ChunkModel(PAPER_PARAMS)
        lo, hi = cm.eta_bounds()
        eta_star, t_star = cm.optimal_eta()
        assert cm.wall_time(lo)["total"] > t_star
        assert cm.wall_time(hi)["total"] > t_star


class TestModelStructure:
    def test_map_term_linear_in_eta(self):
        cm = ChunkModel(PAPER_PARAMS)
        m1 = cm.wall_time(40)["map"]
        m2 = cm.wall_time(80)["map"]
        m3 = cm.wall_time(120)["map"]
        assert (m3 - m2) == pytest.approx(m2 - m1, rel=1e-6)

    def test_components_nonnegative(self):
        cm = ChunkModel(PAPER_PARAMS)
        for eta in (24, 50, 100, 160):
            for part, v in cm.wall_time(eta).items():
                assert v >= 0, (eta, part)
            for part, v in cm.resource_time(eta).items():
                assert v >= 0, (eta, part)

    def test_empty_window_raises(self):
        p = ChunkModelParams(
            n_img=10_000, size_big=1e9, size_small=1e9, size_gen=1e6,
            bandwidth=1e8, v_disc_r=1e8, v_disc_w=1e8,
            mem=1e9, core=2,   # mem/SizeBig = 1 < #img/core = 5000
        )
        with pytest.raises(ValueError):
            ChunkModel(p).eta_bounds()

    def test_resource_time_counts_all_images(self):
        # RT map term must scale with #img, not with the longest task
        p1 = PAPER_PARAMS
        import dataclasses
        p2 = dataclasses.replace(p1, n_img=2 * p1.n_img, core=2 * p1.core)
        r1 = ChunkModel(p1).resource_time(60)["map"]
        r2 = ChunkModel(p2).resource_time(60)["map"]
        assert r2 == pytest.approx(2 * r1, rel=0.01)


class TestTPUTranslation:
    def test_valid_window_and_optimum(self):
        cm = ChunkModel(TPU_V5E_PARAMS)
        lo, hi = cm.eta_bounds()
        assert lo >= 1 and hi > lo
        eta, t = cm.optimal_eta()
        assert lo <= eta <= hi
        assert t > 0

    def test_colocated_map_has_no_network_term(self):
        # beta = 0 -> resource map time independent of bandwidth
        import dataclasses
        p = tpu_chunk_params(n_img=1000, row_bytes=1e6, n_devices=64)
        slow = dataclasses.replace(p, bandwidth=p.bandwidth / 100)
        eta = 16
        assert ChunkModel(p).resource_time(eta)["map"] == pytest.approx(
            ChunkModel(slow).resource_time(eta)["map"]
        )

    def test_tpu_optimum_far_smaller_wall_than_paper(self):
        # sanity: HBM-speed grid finishes orders of magnitude faster
        t_paper = ChunkModel(PAPER_PARAMS).optimal_eta()[1]
        t_tpu = ChunkModel(TPU_V5E_PARAMS).optimal_eta()[1]
        assert t_tpu < t_paper / 100


class TestSpillTerm:
    """tpu_chunk_params' alpha is the real non-resident fraction, not a
    hard-coded zero, and spilled traffic blends HBM with disk bandwidth."""

    FIT = dict(n_img=1000, row_bytes=1e6, n_devices=64)      # 1 GB << fleet
    SPILL = dict(n_img=4000, row_bytes=8e6, n_devices=2)     # 32 GB vs 16 GB

    def test_fitting_dataset_keeps_alpha_zero(self):
        p = tpu_chunk_params(**self.FIT)
        assert p.alpha == 0.0

    def test_fitting_dataset_ignores_disk_rates(self):
        # back-compat: when nothing spills, disk bandwidth is irrelevant
        fast = tpu_chunk_params(**self.FIT)
        slow = tpu_chunk_params(**self.FIT, disk_bw_r=1e6, disk_bw_w=1e6)
        assert (fast.v_disc_r, fast.v_disc_w, fast.alpha) == (
            slow.v_disc_r, slow.v_disc_w, slow.alpha)

    def test_oversubscribed_dataset_spills_exact_fraction(self):
        # mem budget = half of 16 GB HBM x 2 devices = 16 GB; dataset 32 GB
        p = tpu_chunk_params(**self.SPILL)
        assert p.alpha == pytest.approx(0.5)

    def test_blend_is_harmonic_and_monotone_in_disk_rate(self):
        hbm = tpu_chunk_params(**self.SPILL)          # no disk arg: HBM-speed
        mid = tpu_chunk_params(**self.SPILL, disk_bw_r=300e6)
        slow = tpu_chunk_params(**self.SPILL, disk_bw_r=30e6)
        assert slow.v_disc_r < mid.v_disc_r < hbm.v_disc_r
        # harmonic blend at alpha=0.5, exact
        expect = 1.0 / (0.5 / 819e9 + 0.5 / 300e6)
        assert mid.v_disc_r == pytest.approx(expect)

    def test_spill_term_raises_wall_time(self):
        resident = tpu_chunk_params(**self.FIT, disk_bw_r=300e6)
        spilling = tpu_chunk_params(**self.SPILL, disk_bw_r=300e6)
        eta = 16
        # alpha > 0 adds disc read+write work per generated chunk
        assert ChunkModel(spilling).wall_time(eta)["total"] > 0
        assert resident.alpha == 0.0 and spilling.alpha > 0.0


class TestTierCostModel:
    def test_defaults_prefer_disk_over_refabric(self):
        # local SSD round-trip beats two trips over the 70 MB/s fabric
        cm = TierCostModel()
        assert cm.should_spill_block(10_000_000)
        assert not cm.should_spill_block(0)

    def test_slow_disk_prefers_regather(self):
        cm = TierCostModel(disk_bw_r=1e6, disk_bw_w=1e6)
        assert not cm.should_spill_block(10_000_000)

    def test_partials_spill_when_refold_is_expensive(self):
        cm = TierCostModel()
        # a 1 KB accumulator standing in for a 20 MB source block
        assert cm.should_spill_partial(1_000, 20_000_000)
        assert not cm.should_spill_partial(0, 20_000_000)

    def test_refold_includes_refetch_and_stream(self):
        cm = TierCostModel(refetch_bw=70e6, fold_bw=819e9,
                           fold_overhead=5e-6)
        n = 20_000_000
        assert cm.refold_s(n) == pytest.approx(
            n / 70e6 + n / 819e9 + 5e-6)

    def test_from_params_uses_model_rates(self):
        cm = TierCostModel.from_params(TPU_V5E_PARAMS)
        assert cm.refetch_bw == TPU_V5E_PARAMS.bandwidth
        assert cm.fold_bw == TPU_V5E_PARAMS.v_disc_r
        # ICI-speed refetch beats any SSD: nothing should spill
        assert not cm.should_spill_block(10_000_000)
