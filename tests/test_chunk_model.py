"""Tests for the paper's eq. (1)-(8) chunk-size model."""

import math

import pytest

from repro.core.chunk_model import (
    ChunkModel,
    ChunkModelParams,
    PAPER_PARAMS,
    TPU_V5E_PARAMS,
    tpu_chunk_params,
)


class TestPaperReproduction:
    """Validates the model against the paper's own claims (§2.4.3, §3.2)."""

    def test_eta_window_matches_paper(self):
        lo, hi = ChunkModel(PAPER_PARAMS).eta_bounds()
        # paper assesses eta in [30, 160]: the upper bound is exact
        # (mem/SizeBig = 160); the lower bound the paper rounds up from
        # max(#img*SizeSmall/mem, #img/core) = max(9.7, 23.0) = 23.
        assert hi == 160
        assert lo == math.ceil(5153 / 224) == 24

    def test_optimal_eta_in_paper_band(self):
        eta, _ = ChunkModel(PAPER_PARAMS).optimal_eta(metric="wall")
        assert 50 <= eta <= 62  # paper: optimum observed at 50-60

    def test_resource_time_flat_beyond_80(self):
        # paper: "when chunk size more than 80, the resource time becomes
        # similar" — the curve must flatten: relative change < 5% from 80->160
        cm = ChunkModel(PAPER_PARAMS)
        r80 = cm.resource_time(80)["total"]
        r160 = cm.resource_time(160)["total"]
        assert abs(r160 - r80) / r80 < 0.05

    def test_wall_time_u_shape(self):
        cm = ChunkModel(PAPER_PARAMS)
        lo, hi = cm.eta_bounds()
        eta_star, t_star = cm.optimal_eta()
        assert cm.wall_time(lo)["total"] > t_star
        assert cm.wall_time(hi)["total"] > t_star


class TestModelStructure:
    def test_map_term_linear_in_eta(self):
        cm = ChunkModel(PAPER_PARAMS)
        m1 = cm.wall_time(40)["map"]
        m2 = cm.wall_time(80)["map"]
        m3 = cm.wall_time(120)["map"]
        assert (m3 - m2) == pytest.approx(m2 - m1, rel=1e-6)

    def test_components_nonnegative(self):
        cm = ChunkModel(PAPER_PARAMS)
        for eta in (24, 50, 100, 160):
            for part, v in cm.wall_time(eta).items():
                assert v >= 0, (eta, part)
            for part, v in cm.resource_time(eta).items():
                assert v >= 0, (eta, part)

    def test_empty_window_raises(self):
        p = ChunkModelParams(
            n_img=10_000, size_big=1e9, size_small=1e9, size_gen=1e6,
            bandwidth=1e8, v_disc_r=1e8, v_disc_w=1e8,
            mem=1e9, core=2,   # mem/SizeBig = 1 < #img/core = 5000
        )
        with pytest.raises(ValueError):
            ChunkModel(p).eta_bounds()

    def test_resource_time_counts_all_images(self):
        # RT map term must scale with #img, not with the longest task
        p1 = PAPER_PARAMS
        import dataclasses
        p2 = dataclasses.replace(p1, n_img=2 * p1.n_img, core=2 * p1.core)
        r1 = ChunkModel(p1).resource_time(60)["map"]
        r2 = ChunkModel(p2).resource_time(60)["map"]
        assert r2 == pytest.approx(2 * r1, rel=0.01)


class TestTPUTranslation:
    def test_valid_window_and_optimum(self):
        cm = ChunkModel(TPU_V5E_PARAMS)
        lo, hi = cm.eta_bounds()
        assert lo >= 1 and hi > lo
        eta, t = cm.optimal_eta()
        assert lo <= eta <= hi
        assert t > 0

    def test_colocated_map_has_no_network_term(self):
        # beta = 0 -> resource map time independent of bandwidth
        import dataclasses
        p = tpu_chunk_params(n_img=1000, row_bytes=1e6, n_devices=64)
        slow = dataclasses.replace(p, bandwidth=p.bandwidth / 100)
        eta = 16
        assert ChunkModel(p).resource_time(eta)["map"] == pytest.approx(
            ChunkModel(slow).resource_time(eta)["map"]
        )

    def test_tpu_optimum_far_smaller_wall_than_paper(self):
        # sanity: HBM-speed grid finishes orders of magnitude faster
        t_paper = ChunkModel(PAPER_PARAMS).optimal_eta()[1]
        t_tpu = ChunkModel(TPU_V5E_PARAMS).optimal_eta()[1]
        assert t_tpu < t_paper / 100
