"""Serve a small model with batched requests: prefill + cached decode.

Uses the same serve_step the decode dry-run cells lower.  Checks that
greedy decoding through the cache matches teacher-forced logits.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = ModelConfig(
        name="serve-demo", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab=1024,
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.param_count()/1e6:.1f}M params")

    B, S_prompt, new = 4, 12, 24
    prompts = np.asarray(
        jax.random.randint(jax.random.key(1), (B, S_prompt), 0, cfg.vocab),
        np.int32)
    engine = ServeEngine(cfg, params, capacity=S_prompt + new + 1,
                         batch_size=B)

    import time
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=new)
    dt = time.perf_counter() - t0
    print(f"generated {B}x{new} tokens in {dt:.2f}s "
          f"({B*new/dt:.0f} tok/s on CPU)")
    for b in range(B):
        print(f"  req {b}: {prompts[b].tolist()} -> {out.tokens[b].tolist()}")

    # correctness: greedy decode must equal argmax of teacher-forced logits
    full = np.concatenate([prompts, out.tokens], axis=1)
    logits, _ = model.forward_train(params, jnp.asarray(full))
    want = np.asarray(jnp.argmax(logits[:, S_prompt - 1:-1], axis=-1))
    match = (want == out.tokens).mean()
    print(f"teacher-forced agreement: {match*100:.1f}% "
          f"({'OK' if match == 1.0 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
