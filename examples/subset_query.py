"""Use case 3 end-to-end: age/sex-specific templates via the table scheme.

Runs the paper's Table-3 queries against BOTH table schemes, showing the
byte-accounting difference (index-only scan vs full image traversal), then
computes the subset average on the mesh with locality preserved.

    PYTHONPATH=src python examples/subset_query.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.core.balancer import NodeSpec
from repro.core.mapreduce import MapReduceEngine
from repro.core.placement import Placement
from repro.core.query import (
    age_sex_predicate,
    indexed_query,
    mask_to_device_layout,
    naive_query,
)
from repro.core.stats import MeanProgram
from repro.core.table import ColumnSpec, make_naive_table
from repro.data.pipeline import synthetic_image_population
from repro.utils import make_mesh


def main():
    pop = synthetic_image_population(payload_shape=(6, 6, 6), scale=0.1)
    naive = make_naive_table(
        payload_shape=(6, 6, 6),
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)])
    naive.upload([k.decode() for k in pop.keys],
                 {"img": {"data": pop.column("img", "data"),
                          "size": pop.column("idx", "size"),
                          "age": pop.column("idx", "age"),
                          "sex": pop.column("idx", "sex")}})
    print(f"population: {pop.num_rows} subjects, "
          f"{pop.total_bytes()/1e9:.2f} GB logical\n")

    mesh = make_mesh((jax.device_count(),), ("data",))
    D = mesh.shape["data"]
    pl = Placement.from_strategy(
        pop, [NodeSpec(i) for i in range(D)], "greedy")
    vals, valid = pl.put_column(mesh, "img", "data", chunk_size=16)
    row_ids, vl = pl.device_layout(chunk_size=16)
    engine = MapReduceEngine(mesh)

    for label, lo, hi, sex in [("female 20-40", 20, 40, 1),
                               ("male >60", 60, None, 0),
                               ("all female", None, None, 1)]:
        pred = age_sex_predicate(lo, hi, sex)
        m_p, st_p = indexed_query(pop, pred, ["age", "sex"])
        m_n, st_n = naive_query(naive, pred, ["age", "sex"])
        assert (m_p == m_n).all()

        dm = mask_to_device_layout(m_p, row_ids, vl)
        avg, stats = engine.run(
            MeanProgram(), vals, valid, 16,
            row_mask=jax.device_put(dm, pl.data_sharding(mesh)))
        ref = pop.column("img", "data")[m_p].mean(axis=0)
        err = float(np.abs(np.asarray(avg) - ref).max())

        print(f"{label:14s} n={st_p.rows_selected:5d}")
        print(f"  proposed scheme scanned {st_p.total_bytes_scanned:>14,} B "
              f"(index only)")
        print(f"  naive scheme scanned    {st_n.total_bytes_scanned:>14,} B "
              f"({st_n.total_bytes_scanned/max(st_p.total_bytes_scanned,1):,.0f}x"
              f" more — full image traversal)")
        print(f"  subset template err vs numpy: {err:.2e}\n")


if __name__ == "__main__":
    main()
