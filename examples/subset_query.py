"""Use case 3 end-to-end: age/sex templates through GridQuery job plans.

Runs the paper's Table-3 queries against BOTH table schemes — now through
the lazy ``GridQuery`` builder::

    session.scan(prefix=...).select(col).where(pred).map(prog).reduce()

Nothing moves until ``.collect()``; the planner then (1) prunes regions a
rowkey prefix/range cannot touch (``regions_pruned``), (2) gathers only the
selected column's selected rows, and (3) fuses every mapped statistic into
one shard_map pass.  The naive scheme answers the same predicates but drags
every image's bytes through the read path (Fig. 1C).

    PYTHONPATH=src python examples/subset_query.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate, naive_query
from repro.core.stats import MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table, make_naive_table
from repro.data.pipeline import synthetic_image_population

SITES = ("site-a/", "site-b/", "site-c/", "site-d/")


def multi_site_table(pop):
    """Re-key the population under per-site rowkey prefixes, presplit so
    each site is (at least) its own region — the layout the paper's rowkey
    scheme recommends, and what makes prefix scans prunable."""
    t = make_mip_table(
        payload_shape=pop.column("img", "data").shape[1:],
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)],
        presplit_keys=list(SITES)[1:])
    keys = [f"{SITES[i % len(SITES)]}{k.decode()}"
            for i, k in enumerate(pop.keys)]
    t.upload(keys, {"img": {"data": pop.column("img", "data")},
                    "idx": {"size": pop.column("idx", "size"),
                            "age": pop.column("idx", "age"),
                            "sex": pop.column("idx", "sex")}})
    return t


def main():
    pop = synthetic_image_population(payload_shape=(6, 6, 6), scale=0.1)
    naive = make_naive_table(
        payload_shape=(6, 6, 6),
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)])
    naive.upload([k.decode() for k in pop.keys],
                 {"img": {"data": pop.column("img", "data"),
                          "size": pop.column("idx", "size"),
                          "age": pop.column("idx", "age"),
                          "sex": pop.column("idx", "sex")}})
    print(f"population: {pop.num_rows} subjects, "
          f"{pop.total_bytes()/1e9:.2f} GB logical\n")

    session = GridSession(pop, default_eta=16)

    print("— Table-3 subset templates (predicate pushdown, fused stats) —")
    for label, lo, hi, sex in [("female 20-40", 20, 40, 1),
                               ("male >60", 60, None, 0),
                               ("all female", None, None, 1)]:
        pred = age_sex_predicate(lo, hi, sex)
        # one plan, one gather, one compiled pass: mean AND variance fused
        plan = (session.scan()
                .select("img:data")
                .where(pred, ["age", "sex"])
                .map(MeanProgram())
                .map(VarianceProgram())
                .reduce())
        (avg, var), report = plan.collect()
        st_p = report.query
        m_n, st_n = naive_query(naive, pred, ["age", "sex"])

        ref = pop.column("img", "data")[m_n].mean(axis=0)
        err = float(np.abs(np.asarray(avg) - ref).max())
        assert st_p.rows_selected == st_n.rows_selected

        print(f"{label:14s} n={st_p.rows_selected:5d}")
        print(f"  proposed scheme scanned {st_p.total_bytes_scanned:>14,} B "
              f"(index only)")
        print(f"  payload moved on-shard  {st_p.payload_bytes_moved:>14,} B "
              f"(selected rows only)")
        print(f"  naive scheme scanned    {st_n.total_bytes_scanned:>14,} B "
              f"({st_n.total_bytes_scanned/max(st_p.total_bytes_scanned,1):,.0f}x"
              f" more — full image traversal)")
        print(f"  subset template err vs numpy: {err:.2e} "
              f"(var also computed, same pass)\n")

    print("— rowkey-prefix region pruning (multi-site layout) —")
    sited = multi_site_table(pop)
    site_session = GridSession(sited, default_eta=16)
    plan = site_session.scan(prefix="site-b/").map(MeanProgram())
    print(plan.explain())
    _, report = plan.collect()
    q = report.query
    print(f"  regions: {q.regions_scanned} scanned, {q.regions_pruned} "
          f"pruned (never touched)")
    print(f"  rows selected {q.rows_selected}, payload moved "
          f"{q.payload_bytes_moved:,} B — one site's worth, not the grid's\n")

    print(session.describe())


if __name__ == "__main__":
    main()
