"""Use case 3 end-to-end: age/sex-specific templates via the table scheme.

Runs the paper's Table-3 queries against BOTH table schemes.  The proposed
scheme goes through ``GridSession.run_where`` — predicate pushdown: the index
family answers the predicate, then each device gathers only ITS OWN selected
payload rows, so ``payload_bytes_moved`` covers the subset and nothing else.
The naive scheme answers the same predicate but drags every image's bytes
through the read path (Fig. 1C).

    PYTHONPATH=src python examples/subset_query.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.grid import GridSession
from repro.core.query import age_sex_predicate, naive_query
from repro.core.stats import MeanProgram
from repro.core.table import ColumnSpec, make_naive_table
from repro.data.pipeline import synthetic_image_population


def main():
    pop = synthetic_image_population(payload_shape=(6, 6, 6), scale=0.1)
    naive = make_naive_table(
        payload_shape=(6, 6, 6),
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("sex", (), np.int8)])
    naive.upload([k.decode() for k in pop.keys],
                 {"img": {"data": pop.column("img", "data"),
                          "size": pop.column("idx", "size"),
                          "age": pop.column("idx", "age"),
                          "sex": pop.column("idx", "sex")}})
    print(f"population: {pop.num_rows} subjects, "
          f"{pop.total_bytes()/1e9:.2f} GB logical\n")

    session = GridSession(pop, default_eta=16)

    for label, lo, hi, sex in [("female 20-40", 20, 40, 1),
                               ("male >60", 60, None, 0),
                               ("all female", None, None, 1)]:
        pred = age_sex_predicate(lo, hi, sex)
        avg, report = session.run_where(pred, MeanProgram(), ["age", "sex"])
        st_p = report.query
        m_n, st_n = naive_query(naive, pred, ["age", "sex"])

        ref = pop.column("img", "data")[m_n].mean(axis=0)
        err = float(np.abs(np.asarray(avg) - ref).max())
        assert st_p.rows_selected == st_n.rows_selected

        print(f"{label:14s} n={st_p.rows_selected:5d}")
        print(f"  proposed scheme scanned {st_p.total_bytes_scanned:>14,} B "
              f"(index only)")
        print(f"  payload moved on-shard  {st_p.payload_bytes_moved:>14,} B "
              f"(selected rows only)")
        print(f"  naive scheme scanned    {st_n.total_bytes_scanned:>14,} B "
              f"({st_n.total_bytes_scanned/max(st_p.total_bytes_scanned,1):,.0f}x"
              f" more — full image traversal)")
        print(f"  subset template err vs numpy: {err:.2e}\n")

    print(session.describe())


if __name__ == "__main__":
    main()
