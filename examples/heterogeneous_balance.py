"""Use case 1 + fault tolerance: the balancer as a living scheduler.

Walks through the paper's heterogeneous-cluster story and ColoGrid's
extensions on top of it:

1. default (balanced) vs greedy #CPU×MIPS allocation on the paper's
   224-core grid — simulated wall/resource times;
2. straggler mitigation: a node silently slows 3×, the GridScheduler's
   EWMA powers detect it and the offline rebalance shifts regions away;
3. failure: a node dies, its regions are adopted by survivors;
4. elastic join: a fast node arrives and takes a proportional share.

    PYTHONPATH=src python examples/heterogeneous_balance.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.balancer import (
    NodeSpec,
    allocation_imbalance,
    balanced_allocation,
    greedy_allocation,
)
from repro.core.placement import Placement
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.scheduler import GridScheduler
from repro.core.simulator import ClusterSim, SimTask, paper_cluster
from repro.core.table import ColumnSpec, make_mip_table


def part1_paper_balancer():
    print("=" * 64)
    print("1. heterogeneous cluster: default vs greedy (paper Fig. 3)")
    print("=" * 64)
    nodes = paper_cluster()
    rng = np.random.default_rng(0)
    region_bytes = {i: int(b) for i, b in
                    enumerate(rng.integers(150e6, 220e6, 416))}
    region_of = rng.integers(0, 416, 1200)
    for name, alloc in (
            ("balanced (HBase default)", balanced_allocation(region_bytes, nodes)),
            ("greedy #CPU×MIPS (paper)", greedy_allocation(region_bytes, nodes))):
        tasks = [SimTask(i, 15e6, 8.9e6, work=48.0,
                         home_node=alloc[region_of[i]])
                 for i in range(1200)]
        res = ClusterSim(nodes, bandwidth=70e6).run(tasks, "hadoop")
        imb = allocation_imbalance(alloc, region_bytes, nodes)
        print(f"  {name:28s} wall={res.wall_time:7.1f}s "
              f"resource={res.resource_time:9.0f}s imbalance={imb:.3f}")
    print()


def build_placement(n_nodes=4, n_rows=512):
    rng = np.random.default_rng(1)
    t = make_mip_table(payload_shape=(2,),
                       split_policy=HierarchicalSplitPolicy(int(120e6)))
    t.upload([f"r{i:05d}" for i in range(n_rows)],
             {"img": {"data": rng.normal(size=(n_rows, 2)).astype(np.float32)},
              "idx": {"size": rng.integers(6e6, 20e6, n_rows)}})
    nodes = [NodeSpec(i, cores=1, mips=1.0) for i in range(n_nodes)]
    return t, Placement.from_strategy(t, nodes, "greedy")


def part2_straggler():
    print("=" * 64)
    print("2. straggler mitigation (EWMA powers -> rebalance)")
    print("=" * 64)
    t, pl = build_placement()
    sched = GridScheduler(pl, chunk_size=8, rebalance_threshold=0.25,
                          min_rounds_between_rebalance=2)
    print(f"  initial rows/node: {pl.node_row_counts()}")
    for rnd in range(10):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0}  # node 3 is slow
        ev = sched.observe_round(times)
        if ev:
            print(f"  round {rnd}: REBALANCE ({ev.reason}), moved "
                  f"{len(ev.moved_regions)} regions, imbalance "
                  f"{ev.imbalance_before:.2f} -> {ev.imbalance_after:.2f}")
    print(f"  final rows/node:   {pl.node_row_counts()}  "
          f"(node 3 deweighted)\n")


def part3_failure_and_join():
    print("=" * 64)
    print("3. failure handling + elastic join")
    print("=" * 64)
    t, pl = build_placement()
    sched = GridScheduler(pl, chunk_size=8)
    print(f"  rows/node: {pl.node_row_counts()}")
    ev = sched.handle_failure([2])
    print(f"  node 2 died -> {len(ev.moved_regions)} regions adopted; "
          f"rows/node now {pl.node_row_counts()}")
    ev = sched.handle_join([NodeSpec(9, cores=1, mips=2.0)])
    print(f"  fast node 9 joined -> {len(ev.moved_regions)} regions moved; "
          f"rows/node now {pl.node_row_counts()}")
    counts = pl.node_row_counts()
    assert counts[9] == max(counts.values())
    print("  (node 9, 2x faster, now holds the largest share)\n")


if __name__ == "__main__":
    part1_paper_balancer()
    part2_straggler()
    part3_failure_and_join()
