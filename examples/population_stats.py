"""Use case 2 end-to-end: population template via the GridSession facade.

The paper's §2.2 pipeline on a real (CPU) mesh: synthetic T1 population in
a TensorTable behind a :class:`GridSession`, greedy placement, chunk size η*
from the eq. (1)-(8) model (TPU-translated constants), then ``session.run``
averages the dataset with the Pallas streaming-stats kernel as the map fold —
validated against the jnp oracle, with the byte accounting the colocation
claim rests on.  The second ``run`` shows the compiled-plan cache: same
program + same epoch = no new executable.

    PYTHONPATH=src python examples/population_stats.py --scale 0.05
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.chunk_model import ChunkModel, tpu_chunk_params
from repro.core.grid import GridSession
from repro.core.stats import MeanProgram, VarianceProgram
from repro.data.pipeline import synthetic_image_population
from repro.kernels.streaming_stats.ops import KernelMeanProgram


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05,
                    help="fraction of the 5,153-subject population")
    ap.add_argument("--payload", type=int, default=8,
                    help="volume side (payload = side^3 voxels)")
    args = ap.parse_args()

    table = synthetic_image_population(
        payload_shape=(args.payload,) * 3, scale=args.scale)
    print(f"population: {table.num_rows} subjects, "
          f"{table.total_bytes()/1e9:.1f} GB logical "
          f"({len(table.regions)} regions)")

    session = GridSession(table)
    D = session.mesh.shape["data"]

    # chunk size from the TPU-translated model
    row_bytes = float(np.mean(table.row_bytes()))
    cm = ChunkModel(tpu_chunk_params(
        n_img=table.num_rows, row_bytes=row_bytes, n_devices=D))
    try:
        lo, hi = cm.eta_bounds()
        eta, pred = cm.optimal_eta()
        print(f"chunk model: eta in [{lo}, {hi}], eta*={eta} "
              f"(predicted wall {pred*1e3:.2f} ms at TPU rates)")
    except ValueError as e:
        # single-wave window empty on this tiny device count: run multi-wave
        # at the memory-bound chunk size (the engine handles extra rounds)
        hi = int(cm.p.mem / cm.p.size_big)
        eta = max(min(hi, 512), 1)
        print(f"chunk model: {e}\n  -> multi-wave fallback, eta={eta}")

    mean_k, report = session.run(KernelMeanProgram(), eta=eta)
    stats = report.mapreduce
    mean_ref = table.column("img", "data").mean(axis=0)
    err = float(np.abs(np.asarray(mean_k) - mean_ref).max())
    print(f"\nkernel mean over {stats.local_rows_read} rows: "
          f"max err vs numpy = {err:.2e}")
    print(f"  local payload bytes read : {stats.local_bytes_read:,}")
    print(f"  shuffle bytes (network)  : {stats.shuffle_bytes:,}  "
          f"({stats.shuffle_bytes/max(stats.local_bytes_read,1)*100:.3f}% "
          f"of payload — the colocation win)")
    print(f"  rounds={stats.rounds} chunks={stats.chunks} eta={eta}")

    compiles_before = session.engine.compile_count
    _, report2 = session.run(KernelMeanProgram(), eta=eta)
    print(f"repeat run: plan_cache_hit={report2.plan_cache_hit}, "
          f"new compiles={session.engine.compile_count - compiles_before}")

    var, _ = session.run(VarianceProgram(), eta=eta)
    verr = float(np.abs(np.asarray(var["var"])
                        - table.column("img", "data").var(axis=0)).max())
    print(f"variance (Chan parallel merge): max err = {verr:.2e}")

    # --- grouped analytics: per-stratum mean/variance in ONE pass --------
    # Real cohorts are stratified (per-site, per-scanner, per-sex): one
    # group_by plan folds group-keyed partials per block instead of one
    # query per stratum — same gathers, same partial cache, G answers.
    grouped, grep = (session.scan().select("img:data").group_by("idx:sex")
                     .map(MeanProgram()).map(VarianceProgram())
                     .reduce().collect(eta=eta))
    data = table.column("img", "data")
    sexes = table.column("idx", "sex")
    gmean, gvar = grouped.values
    print(f"\ngrouped (per-sex) stats over {grep.query.num_groups} strata "
          f"in one pass (gathers={grep.query.gather_count}):")
    for g, sex in enumerate(grouped.keys):
        ref = data[sexes == sex]
        gerr = float(np.abs(np.asarray(gmean)[g] - ref.mean(0)).max())
        print(f"  sex={int(sex)}: n={len(ref)}, "
              f"mean max err vs numpy groupby = {gerr:.2e}")
    _, grep2 = (session.scan().select("img:data").group_by("idx:sex")
                .map(MeanProgram()).map(VarianceProgram())
                .reduce().collect(eta=eta))
    print(f"repeat grouped query: rows_folded={grep2.query.rows_folded} "
          f"(group-keyed partials cached)")
    print()
    print(session.describe())


if __name__ == "__main__":
    main()
