"""Use case 2 end-to-end: population template via colocated MapReduce.

The paper's §2.2 pipeline on a real (CPU) mesh: synthetic T1 population in
a TensorTable, greedy placement, chunk size η* from the eq. (1)-(8) model
(TPU-translated constants), then the MapReduce engine averages the dataset
with the Pallas streaming-stats kernel as the map fold — validated against
the jnp oracle, with the byte accounting the colocation claim rests on.

    PYTHONPATH=src python examples/population_stats.py --scale 0.05
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

import jax

from repro.core.balancer import NodeSpec
from repro.core.chunk_model import ChunkModel, tpu_chunk_params
from repro.core.mapreduce import MapReduceEngine
from repro.core.placement import Placement
from repro.core.stats import MeanProgram, VarianceProgram
from repro.data.pipeline import synthetic_image_population
from repro.kernels.streaming_stats.ops import KernelMeanProgram
from repro.utils import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05,
                    help="fraction of the 5,153-subject population")
    ap.add_argument("--payload", type=int, default=8,
                    help="volume side (payload = side^3 voxels)")
    args = ap.parse_args()

    table = synthetic_image_population(
        payload_shape=(args.payload,) * 3, scale=args.scale)
    print(f"population: {table.num_rows} subjects, "
          f"{table.total_bytes()/1e9:.1f} GB logical "
          f"({len(table.regions)} regions)")

    mesh = make_mesh((jax.device_count(),), ("data",))
    D = mesh.shape["data"]
    nodes = [NodeSpec(i, cores=1, mips=1.0) for i in range(D)]
    pl = Placement.from_strategy(table, nodes, "greedy")

    # chunk size from the TPU-translated model
    row_bytes = float(np.mean(table.row_bytes()))
    cm = ChunkModel(tpu_chunk_params(
        n_img=table.num_rows, row_bytes=row_bytes, n_devices=D))
    try:
        lo, hi = cm.eta_bounds()
        eta, pred = cm.optimal_eta()
        print(f"chunk model: eta in [{lo}, {hi}], eta*={eta} "
              f"(predicted wall {pred*1e3:.2f} ms at TPU rates)")
    except ValueError as e:
        # single-wave window empty on this tiny device count: run multi-wave
        # at the memory-bound chunk size (the engine handles extra rounds)
        hi = int(cm.p.mem / cm.p.size_big)
        eta = max(min(hi, 512), 1)
        print(f"chunk model: {e}\n  -> multi-wave fallback, eta={eta}")

    vals, valid = pl.put_column(mesh, "img", "data", chunk_size=eta)
    engine = MapReduceEngine(mesh)

    mean_k, stats = engine.run(KernelMeanProgram(), vals, valid, eta)
    mean_ref = table.column("img", "data").mean(axis=0)
    err = float(np.abs(np.asarray(mean_k) - mean_ref).max())
    print(f"\nkernel mean over {stats.local_rows_read} rows: "
          f"max err vs numpy = {err:.2e}")
    print(f"  local payload bytes read : {stats.local_bytes_read:,}")
    print(f"  shuffle bytes (network)  : {stats.shuffle_bytes:,}  "
          f"({stats.shuffle_bytes/max(stats.local_bytes_read,1)*100:.3f}% "
          f"of payload — the colocation win)")
    print(f"  rounds={stats.rounds} chunks={stats.chunks} eta={eta}")

    var, _ = engine.run(VarianceProgram(), vals, valid, eta)
    verr = float(np.abs(np.asarray(var["var"])
                        - table.column("img", "data").var(axis=0)).max())
    print(f"variance (Chan parallel merge): max err = {verr:.2e}")


if __name__ == "__main__":
    main()
