"""Quickstart: train a small LM end-to-end through the ColoGrid stack.

Every layer of the framework is exercised: synthetic corpus stored in a
TensorTable, regions placed by the greedy balancer, the colocated data
pipeline feeding a jitted train step (AdamW + schedule + grad accumulation),
periodic async checkpoints, and resume.

    PYTHONPATH=src python examples/quickstart.py --steps 200 --preset small
    PYTHONPATH=src python examples/quickstart.py --preset 100m --steps 300
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.grid import GridSession
from repro.data.pipeline import synthetic_token_table
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup_cosine
from repro.train.step import TrainStepConfig, make_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig
from repro.utils import make_mesh

PRESETS = {
    # ~6M params — seconds/step on one CPU core
    "small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                  d_ff=512, vocab=2048, seq=128, batch=8),
    # ~25M params
    "base": dict(n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
                 d_ff=1024, vocab=4096, seq=256, batch=8),
    # ~100M params — the assignment's end-to-end driver scale
    "100m": dict(n_layers=10, d_model=512, n_heads=8, n_kv_heads=4,
                 d_ff=2048, vocab=16384, seq=256, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/cologrid_quickstart")
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"quickstart-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"], vocab=p["vocab"],
        remat_policy="none", dtype=jnp.float32, param_dtype=jnp.float32,
    )
    model = build_model(cfg)
    params, opt_state = make_train_state(cfg, model, jax.random.key(0))
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params, preset={args.preset}")

    mesh = make_mesh((jax.device_count(),), ("data",))
    table = synthetic_token_table(
        n_rows=2048, seq_len=p["seq"] + 1, vocab=p["vocab"])
    session = GridSession(table, mesh=mesh)
    print(f"corpus: {table.num_rows} docs in {len(table.regions)} regions, "
          f"{table.total_bytes()/1e6:.1f} MB "
          f"(imbalance {session.imbalance():.3f})")
    ds = session.token_dataset(global_batch=p["batch"])

    schedule = lambda s: linear_warmup_cosine(s, 20, args.steps)
    step = jax.jit(make_train_step(
        cfg, model, AdamWConfig(lr=3e-4),
        TrainStepConfig(num_microbatches=args.microbatches,
                        schedule=schedule)))

    trainer = Trainer(step, ds, TrainerConfig(
        total_steps=args.steps, log_every=10, checkpoint_every=50,
        checkpoint_dir=args.ckpt_dir))
    params, opt_state, history = trainer.run(params, opt_state)

    if not history:
        print(f"\nresumed checkpoint is already at/past --steps {args.steps}; "
              f"nothing to train (pass a higher --steps or a fresh --ckpt-dir)")
        return
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'OK' if last < first else 'NOT DECREASING'})")
    print(f"checkpoints in {args.ckpt_dir} (resume by re-running)")


if __name__ == "__main__":
    main()
