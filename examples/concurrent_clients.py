"""Multi-site population statistics under concurrent load.

Eight client threads hammer one :class:`GridFrontend` with a mixed
workload — repeat whole-population statistics (single-flight coalescing),
per-site grouped queries with distinct programs (batched device ticks),
and a mid-run upload of a new scan batch (epoch-isolated mutation that
drains in-flight queries) — then the frontend's observability surface
shows what the serving layer shared.

    PYTHONPATH=src python examples/concurrent_clients.py
"""

import argparse
import sys
import threading
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.frontend import GridFrontend
from repro.core.grid import GridSession
from repro.core.regions import HierarchicalSplitPolicy
from repro.core.stats import CountProgram, MeanProgram, VarianceProgram
from repro.core.table import ColumnSpec, make_mip_table

N_SITES = 4
ROWS_PER_SITE = 64
PAYLOAD = (8, 8)
CLIENTS = 8


def make_sites(seed=0):
    rng = np.random.default_rng(seed)
    t = make_mip_table(
        payload_shape=PAYLOAD,
        extra_index_columns=[ColumnSpec("age", (), np.float32),
                             ColumnSpec("site", (), np.int8)],
        # region volume tracks the logical idx:size column (6-20 MB/row);
        # ~16 rows per region at this bound
        split_policy=HierarchicalSplitPolicy(max_region_bytes=2 * 10**8),
    )
    n = N_SITES * ROWS_PER_SITE
    t.upload(
        [f"site{i % N_SITES}/img{i:05d}" for i in range(n)],
        {"img": {"data": rng.normal(size=(n,) + PAYLOAD)
                 .astype(np.float32)},
         "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                 "age": rng.uniform(4, 80, n).astype(np.float32),
                 "site": (np.arange(n) % N_SITES).astype(np.int8)}},
    )
    return t


def new_scan_batch(seed):
    rng = np.random.default_rng(seed)
    keys = [f"site0/new{seed}_{j:03d}" for j in range(8)]
    n = len(keys)
    return keys, {
        "img": {"data": rng.normal(size=(n,) + PAYLOAD)
                .astype(np.float32)},
        "idx": {"size": rng.integers(6_000_000, 20_000_001, n),
                "age": rng.uniform(4, 80, n).astype(np.float32),
                "site": np.zeros(n, np.int8)}}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=40,
                    help="queries per client")
    args = ap.parse_args()

    t = make_sites()
    s = GridSession(t, default_eta=8)
    print(f"population: {t.num_rows} rows across {N_SITES} sites "
          f"({len(t.regions)} regions)")

    with GridFrontend(s, workers=CLIENTS, tick_ms=2.0) as fe:
        # a shared plan pool: one repeat statistic + three distinct
        # programs over the same per-site grouped scan
        pop_mean = s.scan().map(MeanProgram()).reduce()
        by_site = s.scan().group_by("idx:site")
        site_plans = [by_site.map(MeanProgram()).reduce(),
                      by_site.map(VarianceProgram()).reduce(),
                      by_site.map(CountProgram()).reduce()]
        plans = [pop_mean] * 3 + site_plans     # repeat-heavy mix

        errors = []
        barrier = threading.Barrier(CLIENTS + 1)

        def client(i):
            try:
                barrier.wait()
                for q in range(args.queries):
                    fe.query(plans[(i + q) % len(plans)], timeout=120)
            except BaseException as e:   # noqa: BLE001 — reported below
                errors.append(e)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(CLIENTS)]
        for th in threads:
            th.start()
        barrier.wait()
        t0 = time.perf_counter()

        # mid-run mutation: a new scan batch lands at site 0 while the
        # clients keep querying — drains in-flight work, bumps the epoch
        time.sleep(0.1)
        keys, data = new_scan_batch(seed=1)
        fe.upload(keys, data)
        print(f"mid-run upload of {len(keys)} rows applied at "
              f"epoch {s.epoch}")

        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]

        stats = fe.stats.snapshot()
        p50, p99 = fe.stats.latency_percentiles()
        total = CLIENTS * args.queries
        print(f"\n{total} queries from {CLIENTS} clients in "
              f"{wall:.2f}s ({total / wall:,.0f} queries/s)")
        print(f"  served={stats.served} coalesce_hits="
              f"{stats.coalesce_hits} "
              f"({stats.coalesce_hits / max(stats.submitted, 1):.0%} of "
              f"submissions shared a flight)")
        print(f"  batch_merges={stats.batch_merges} "
              f"batched_queries={stats.batched_queries} "
              f"ticks={stats.ticks} "
              f"partial_coalesce_hits={stats.partial_coalesce_hits}")
        print(f"  mutations={stats.mutations} "
              f"queue_depth_peak={stats.queue_depth_peak} "
              f"p50={p50 * 1e3:.2f}ms p99={p99 * 1e3:.2f}ms")

        # the whole stream hit the device as a handful of executions
        print(f"  session scans={s.metrics.scans} "
              f"(executions for {total} queries), "
              f"block folds={s.blocks.stats.folds}")

        val, _ = fe.query(pop_mean, timeout=120)
        print(f"\npopulation mean checksum: "
              f"{float(np.asarray(val).sum()):+.4f} "
              f"over {t.num_rows} rows")


if __name__ == "__main__":
    main()
